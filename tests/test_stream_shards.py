"""Shard-parallel streaming fold (ISSUE 4, DESIGN.md §7).

The contract tested here: the S-way fold — contiguous block groups,
each folded with the PR 3 left fold, partial AggStates combined by
``tree_merge``'s canonical fixed association — is a **pure function of
(client order, chunk, S)**:

  * ``S == 1`` *is* the sequential sweep — bitwise, for every streaming
    rule (no merge happens at all);
  * per-client criterion logs are bitwise-identical at every S (the
    fold association never touches per-row statistics);
  * executing the same S-way fold on an S-shard mesh is bitwise-equal
    to executing it sequentially on one device (subprocess test with
    forced host devices) — parallel placement cannot change the bits;
  * across *different* shard counts the delta agrees to fp tolerance
    (the log2(S) merge adds reassociate — documented, not hidden).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.data import (FederatedData, make_classification,
                        partition_sorted_shards)
from repro.fl import (FLConfig, Federation, run_federated_training,
                      softmax_regression, stream_aggregate, streaming_rules,
                      tree_merge)
from repro.fl.chunking import group_blocks, resolve_shards
from repro.fl.server import AggregationContext
from repro.fl.streaming import get_streaming
from repro.optim import inv_sqrt_lr

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_CLIENTS, DIM, N_CLASSES = 64, 8, 4


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


# ----------------------------------------------------------------------
# the fold itself: stream_aggregate at S ∈ {1, 2, 4} per rule
# ----------------------------------------------------------------------

def _bound(name, n, d, rng):
    U = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    byz = jnp.asarray(rng.random(n) < 0.3)
    root = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    rule = get_streaming(name).bind(
        AggregationContext(byz_mask=byz, guides=G, root_update=root))

    def block_fn(blk, valid):
        u_blk, g_blk, byz_b = blk
        return u_blk, {"byz": byz_b, "guide": g_blk}

    return rule, block_fn, (U, G, byz)


@pytest.mark.parametrize("name", ["mean", "oracle", "diversefl", "fltrust"])
def test_one_shard_is_sequential_bitwise(name):
    rng = np.random.default_rng(0)
    n, d, chunk = 32, 23, 4
    rule, block_fn, args = _bound(name, n, d, rng)
    d_seq, _, logs_seq = stream_aggregate(rule, block_fn, args, chunk, d=d)
    d_s1, _, logs_s1 = stream_aggregate(rule, block_fn, args, chunk, d=d,
                                        shards=1)
    np.testing.assert_array_equal(np.asarray(d_seq), np.asarray(d_s1))
    for a, b in zip(jax.tree.leaves(logs_seq), jax.tree.leaves(logs_s1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["mean", "oracle", "diversefl", "fltrust"])
@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_fold_per_client_logs_bitwise(name, shards):
    """The merge association never touches per-row statistics: criterion
    logs are bitwise at every shard count."""
    rng = np.random.default_rng(1)
    n, d, chunk = 32, 23, 4
    rule, block_fn, args = _bound(name, n, d, rng)
    d_seq, _, logs_seq = stream_aggregate(rule, block_fn, args, chunk, d=d)
    d_s, _, logs_s = stream_aggregate(rule, block_fn, args, chunk, d=d,
                                      shards=shards)
    for a, b in zip(jax.tree.leaves(logs_seq), jax.tree.leaves(logs_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # delta: S-1 merge adds reassociate -> tight fp tolerance, not bitwise
    np.testing.assert_allclose(np.asarray(d_s), np.asarray(d_seq),
                               rtol=1e-5, atol=1e-6)


def test_sharded_fold_deterministic_per_shard_count():
    """Same S -> same bits, run to run: the association is a pure
    function of (client order, chunk, S)."""
    rng = np.random.default_rng(2)
    n, d, chunk = 32, 17, 4
    rule, block_fn, args = _bound("diversefl", n, d, rng)
    a, _, _ = stream_aggregate(rule, block_fn, args, chunk, d=d, shards=4)
    b, _, _ = stream_aggregate(rule, block_fn, args, chunk, d=d, shards=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_exact_data_sharded_equals_sequential_bitwise():
    """With integer-valued updates and 0/1 weights every add is exact,
    so the S-way tree-merge reproduces the sequential fold bit-for-bit —
    the merge changes association, never the math."""
    rng = np.random.default_rng(3)
    n, d, chunk = 16, 11, 2
    U = jnp.asarray(rng.integers(-8, 8, size=(n, d)).astype(np.float32))
    byz = jnp.asarray(rng.random(n) < 0.3)
    rule = get_streaming("oracle").bind(AggregationContext(byz_mask=byz))

    def block_fn(blk, valid):
        u_blk, byz_b = blk
        return u_blk, {"byz": byz_b}

    d_seq, _, _ = stream_aggregate(rule, block_fn, (U, byz), chunk, d=d)
    for s in (2, 4):
        d_s, _, _ = stream_aggregate(rule, block_fn, (U, byz), chunk, d=d,
                                     shards=s)
        np.testing.assert_array_equal(np.asarray(d_s), np.asarray(d_seq))


# ----------------------------------------------------------------------
# tree_merge: the canonical association
# ----------------------------------------------------------------------

def test_tree_merge_canonical_order():
    """tree_merge(n) == the documented balanced pairwise order — checked
    against a hand-rolled reference, including the odd-tail case."""
    calls = []

    def merge(a, b):
        calls.append((a[1], b[1]))
        return (a[0] + b[0], f"({a[1]}+{b[1]})")

    states = (jnp.arange(5.0), np.array(["s0", "s1", "s2", "s3", "s4"]))
    # hand-build the stacked pytree: leaves with leading axis n
    stacked = (jnp.stack([states[0] + i for i in range(5)]), states[1])
    out = tree_merge(merge, stacked, 5)
    assert out[1] == "(((s0+s1)+(s2+s3))+s4)"


def test_tree_merge_single_state_is_identity():
    state = (jnp.arange(3.0)[None], jnp.ones((1,)))
    out = tree_merge(lambda a, b: pytest.fail("no merge at n=1"), state, 1)
    np.testing.assert_array_equal(np.asarray(out[0]), np.arange(3.0))


def test_resolve_shards_clamps_to_divisor():
    assert resolve_shards(4, 8) == 4
    assert resolve_shards(3, 8) == 2     # largest divisor of 8 below 3
    assert resolve_shards(5, 12) == 4
    assert resolve_shards(16, 4) == 4    # never exceeds the block count
    assert resolve_shards(1, 7) == 1
    assert resolve_shards(7, 7) == 7


def test_group_blocks_requires_divisibility():
    blocks = jnp.zeros((6, 2, 3))
    grouped = group_blocks(blocks, 6, 3)
    assert grouped.shape == (3, 2, 2, 3)
    with pytest.raises(ValueError, match="must divide"):
        group_blocks(blocks, 6, 4)


# ----------------------------------------------------------------------
# training level: FLConfig.stream_shards
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def fed_data():
    x, y = make_classification(jax.random.PRNGKey(0), N_CLIENTS * 8,
                               N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, N_CLASSES, DIM)
    return data, tx, ty


def _train(fed_data, **kw):
    data, tx, ty = fed_data
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    kw.setdefault("n_clients", N_CLIENTS)
    kw.setdefault("f", 12)
    kw.setdefault("rounds", 2)
    kw.setdefault("batch_size", 2)
    kw.setdefault("eval_every", 2)
    kw.setdefault("l2", 0.0)
    kw.setdefault("client_chunk", 8)
    kw.setdefault("streaming", True)
    kw.setdefault("attack", AttackConfig(kind="sign_flip"))
    cfg = FLConfig(**kw)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    return run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))


@pytest.mark.parametrize("aggregator", ["diversefl", "oracle", "mean",
                                        "fltrust"])
def test_training_stream_shards_one_is_sequential(fed_data, aggregator):
    h_seq = _train(fed_data, aggregator=aggregator)
    h_s1 = _train(fed_data, aggregator=aggregator, stream_shards=1)
    assert np.array_equal(_flat(h_seq["params"]), _flat(h_s1["params"]))


@pytest.mark.parametrize("shards", [2, 4])
def test_training_stream_shards_close_and_masks_bitwise(fed_data, shards):
    h_seq = _train(fed_data)
    h_s = _train(fed_data, stream_shards=shards)
    np.testing.assert_allclose(_flat(h_s["params"]), _flat(h_seq["params"]),
                               rtol=1e-5, atol=1e-6)
    # keep-mask counts derive from per-row stats -> bitwise at any S
    assert h_seq["mask_tpr"] == h_s["mask_tpr"]
    assert h_seq["mask_fpr"] == h_s["mask_fpr"]


def test_every_streaming_rule_covered():
    assert set(streaming_rules()) == {"mean", "oracle", "diversefl",
                                      "fltrust"}


def test_sharded_kernel_block_fold_runs(fed_data):
    """use_kernel_agg's per-block Pallas fold composes with the shard
    groups (the kernel vmaps over group lanes); block association was
    already fp-tolerance, so the merge adds stay inside it."""
    h_seq = _train(fed_data)
    h_k = _train(fed_data, use_kernel_agg=True, stream_shards=2)
    np.testing.assert_allclose(_flat(h_k["params"]), _flat(h_seq["params"]),
                               rtol=1e-4, atol=1e-6)


# ----------------------------------------------------------------------
# mesh execution: S shards on S devices == the same fold on one device
# ----------------------------------------------------------------------

def test_mesh_sharded_fold_bitwise_subprocess():
    """At 1/2/4 mesh shards the shard-parallel sweep (client/group axis
    sharded over the mesh's data axes, auto shard count) is bitwise-
    equal to the same fold executed sequentially without a mesh, for
    every streaming rule — parallel placement cannot change the bits."""
    script = """
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.attacks import AttackConfig
    from repro.data import FederatedData, make_classification, \\
        partition_sorted_shards
    from repro.fl import (FLConfig, Federation, RoundEngine,
                          softmax_regression)
    from repro.optim import inv_sqrt_lr

    N, DIM, NC = 64, 8, 4
    x, y = make_classification(jax.random.PRNGKey(0), N * 8, NC, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N), NC)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, NC, DIM)
    model = softmax_regression(input_dim=DIM, n_classes=NC)

    def flat(p):
        return np.concatenate([np.asarray(v).ravel()
                               for v in jax.tree.leaves(p)])

    def segment(agg, mesh=None, **kw):
        cfg = FLConfig(n_clients=N, f=12, rounds=2, batch_size=2,
                       eval_every=2, l2=0.0, client_chunk=8, streaming=True,
                       aggregator=agg, attack=AttackConfig(kind="sign_flip"),
                       **kw)
        fed = Federation.create(model, data, tx, ty, cfg,
                                jax.random.PRNGKey(2))
        eng = RoundEngine(model, fed, cfg, mesh=mesh, batch_mode="segment")
        params = model.init(jax.random.PRNGKey(1))
        lrs = [float(inv_sqrt_lr(0.05)(r)) for r in (1, 2)]
        p, _, logs = eng.run_segment(params, jax.random.PRNGKey(0), lrs)
        return flat(p), logs

    for agg in ("diversefl", "oracle", "mean", "fltrust"):
        for S in (1, 2, 4):
            mesh = Mesh(np.array(jax.devices()[:S]).reshape(S, 1),
                        ("data", "model"))
            # the mesh run auto-resolves shards = S from the data axes;
            # the reference runs the same S-way fold on one device
            p_mesh, lg_mesh = segment(agg, mesh=mesh)
            p_ref, lg_ref = segment(agg, stream_shards=S)
            if agg == "fltrust":
                # pre-existing, fold-independent: fltrust's trust-score
                # sqrt/div subgraph fuses differently once the SPMD
                # partitioner splits the program (1 ULP even with the
                # fold forced sequential on the mesh) — tight tolerance
                assert np.allclose(p_mesh, p_ref, rtol=1e-6,
                                   atol=1e-8), (agg, S)
            else:
                assert np.array_equal(p_mesh, p_ref), (agg, S)
            if "mask" in lg_mesh:
                assert np.array_equal(np.asarray(lg_mesh["mask"]),
                                      np.asarray(lg_ref["mask"])), (agg, S)
    print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert p.returncode == 0, \
        f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-4000:]}"
    assert "OK" in p.stdout
