"""Model-layer unit/property tests: RoPE, norms, MoE dispatch invariants,
ring-buffer cache positions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models import ModelConfig
from repro.models.attention import rope
from repro.models.layers import layer_norm, rms_norm
from repro.models.moe import _capacity, apply_moe, make_moe_params


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8)).astype(jnp.int32)
    y = rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
        rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def dot_at(p):
        rq = rope(q, jnp.full((1, 1), p, jnp.int32), 10_000.0)
        rv = rope(v, jnp.full((1, 1), p + 3, jnp.int32), 10_000.0)
        return float(jnp.vdot(rq, rv))
    assert abs(dot_at(0) - dot_at(17)) < 1e-3


def test_rms_norm_unit_rms():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 7.0
    y = rms_norm(x, jnp.zeros(64))
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_layer_norm_zero_mean_unit_var():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 3 + 5
    y = layer_norm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(y.std(-1), 1.0, rtol=1e-2)


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 200), st.integers(2, 8), st.integers(1, 4))
def test_moe_capacity_formula(t, e, k):
    cfg = ModelConfig(name="m", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=64,
                      layout=(("attn", "moe"),), n_experts=e,
                      top_k=min(k, e), d_expert=16)
    c = _capacity(t, cfg)
    assert c % 8 == 0 and c >= 8
    assert c * e >= t * min(k, e)  # capacity covers perfect balance


def test_moe_uniform_router_keeps_all_tokens():
    """With capacity_factor high enough nothing is dropped and the output
    equals a dense expert-weighted mixture (checked via determinism +
    linearity in the gate)."""
    cfg = ModelConfig(name="m", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab_size=64,
                      layout=(("attn", "moe"),), n_experts=4, top_k=2,
                      d_expert=8, capacity_factor=16.0,
                      dtype="float32", param_dtype="float32")
    p = make_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16)) * 0.3
    out1, aux1 = apply_moe(x, p, cfg)
    out2, aux2 = apply_moe(x, p, cfg)
    np.testing.assert_allclose(out1, out2, rtol=1e-6)  # deterministic
    assert np.isfinite(np.asarray(out1)).all()
    # permutation equivariance over the token axis
    perm = jnp.array([3, 1, 0, 5, 4, 2])
    out_p, _ = apply_moe(x[:, perm], p, cfg)
    np.testing.assert_allclose(out_p, out1[:, perm], rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_overflow():
    """A router collapsed onto one expert must drop tokens beyond C."""
    cfg = ModelConfig(name="m", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab_size=64,
                      layout=(("attn", "moe"),), n_experts=4, top_k=1,
                      d_expert=8, capacity_factor=0.25,
                      dtype="float32", param_dtype="float32")
    p = make_moe_params(jax.random.PRNGKey(0), cfg)
    # bias router to expert 0 (positive inputs => positive logit margin)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16)))
    out, _ = apply_moe(x, p, cfg)
    t = 64
    c = _capacity(t, cfg)
    nonzero = int((jnp.abs(out[0]).sum(-1) > 1e-9).sum())
    assert nonzero <= c  # only C tokens served, the rest dropped


def test_swa_ring_cache_positions():
    from repro.models.attention import self_attention
    from repro.models.layers import dense_init
    cfg = ModelConfig(name="s", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=64,
                      layout=(("swa", "mlp"),), window=4,
                      dtype="float32", param_dtype="float32")
    from repro.models.attention import make_attn_params
    p = make_attn_params(jax.random.PRNGKey(0), cfg)
    B, C = 1, 4
    cache = {"k": jnp.zeros((B, C, 2, 16)), "v": jnp.zeros((B, C, 2, 16))}
    # decode 10 steps; must never error and outputs stay finite
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, 32)) * 0.1
    for t in range(10):
        pos = jnp.full((B, 1), t, jnp.int32)
        o, cache = self_attention(x, p, cfg, pos, window=4,
                                  cache=cache, cache_index=jnp.int32(t))
        assert np.isfinite(np.asarray(o)).all()
