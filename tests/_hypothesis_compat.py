"""Fallback shim for `hypothesis` (absent from the minimal CPU image).

Re-exports the real library when it is installed (requirements-dev.txt
pulls it in for CI).  Otherwise provides a tiny deterministic stand-in:
each strategy enumerates a handful of boundary + interior examples and
``@given`` runs the (capped) cartesian product, so the property tests
still execute meaningful sweeps instead of erroring at collection.

Usage in test modules (replaces ``from hypothesis import ...``):

    from _hypothesis_compat import given, settings, strategies as st
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:
    import itertools

    _MAX_COMBOS = 24

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    class _StrategiesShim:
        @staticmethod
        def integers(min_value, max_value):
            lo, hi = int(min_value), int(max_value)
            return _Strategy(sorted({lo, hi, (lo + hi) // 2, min(lo + 1, hi)}))

        @staticmethod
        def floats(min_value, max_value):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(dict.fromkeys([lo, hi, 0.5 * (lo + hi)]))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    strategies = _StrategiesShim()

    def settings(**_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # The runner must expose a zero-arg signature: pytest inspects
            # it for fixtures, and the strategy parameters are not fixtures.
            def runner():
                combos = itertools.islice(
                    itertools.product(*(s.examples for s in strats)),
                    _MAX_COMBOS)
                for combo in combos:
                    fn(*combo)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
