"""Mesh integration tests — run in subprocesses so the multi-device
XLA host platform doesn't leak into the single-device unit tests."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

# The pod-scale launch layer uses the modern sharding API (explicit
# jax.sharding.AxisType meshes + jax.shard_map).  On older JAX (such as
# the pinned CPU CI build) these attributes don't exist, so the whole
# module is environment-gated; the subprocesses inherit this env.
pytestmark = pytest.mark.skipif(
    not (hasattr(jax.sharding, "AxisType") and hasattr(jax, "shard_map")),
    reason="launch/ sharded round step needs jax.sharding.AxisType + "
           "jax.shard_map (newer JAX than this environment provides)")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-4000:]}"
    return p.stdout


def test_fl_round_step_filters_byzantine_and_learns():
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro import configs, models
    from repro.core.diversefl import DiverseFLConfig
    from repro.launch.train import make_fl_round_step
    from repro.sharding import partition_pytree

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = configs.get("gemma-2b", smoke=True)
    params = models.init(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), partition_pytree(params)))
    key = jax.random.PRNGKey(1)
    inputs = {
        "tokens": jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
        "guide_tokens": jax.random.randint(key, (4, 1, 64), 0, cfg.vocab_size),
        "byz_kind": jnp.array([0, 1, 3, 0], jnp.int32),
        "rng": jnp.zeros((2,), jnp.uint32),
    }
    step = make_fl_round_step(cfg, mesh, DiverseFLConfig(), lr=0.1,
                              donate=False)
    p, m = step(params, inputs)
    mask = [bool(x) for x in m["mask"]]
    assert mask == [True, False, False, True], mask   # sign-flip + x5 caught
    l0 = float(m["loss"])
    for _ in range(5):
        p, m = step(p, inputs)
    assert float(m["loss"]) < l0, (l0, float(m["loss"]))
    print("OK", l0, float(m["loss"]))
    """)
    assert "OK" in out


def test_multipod_mesh_round_step():
    """3-axis (pod, data, model) mesh: the pod axis participates in client
    indexing and the masked aggregation psum."""
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro import configs, models
    from repro.core.diversefl import DiverseFLConfig
    from repro.launch.train import make_fl_round_step
    from repro.launch.mesh import n_clients
    from repro.sharding import partition_pytree

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    assert n_clients(mesh) == 4
    cfg = configs.get("deepseek-moe-16b", smoke=True)
    params = models.init(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), partition_pytree(params)))
    key = jax.random.PRNGKey(1)
    inputs = {
        "tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        "guide_tokens": jax.random.randint(key, (4, 1, 32), 0, cfg.vocab_size),
        "byz_kind": jnp.array([0, 0, 1, 0], jnp.int32),
        "rng": jnp.zeros((2,), jnp.uint32),
    }
    step = make_fl_round_step(cfg, mesh, DiverseFLConfig(), lr=0.05,
                              donate=False)
    p, m = step(params, inputs)
    assert float(m["kept"]) == 3.0, float(m["kept"])
    # params stay replicated across clients: all client slices identical
    print("OK")
    """)
    assert "OK" in out


def test_serve_step_all_families_on_mesh():
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro import configs, models
    from repro.launch.shapes import InputShape, serve_inputs
    from repro.launch.serve import make_serve_step
    from repro.sharding import partition_pytree

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    for aid in ["falcon-mamba-7b", "jamba-v0.1-52b", "whisper-medium"]:
        cfg = configs.get(aid, smoke=True)
        params = models.init(jax.random.PRNGKey(0), cfg)
        params = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), partition_pytree(params)))
        specs, _ = serve_inputs(cfg, InputShape("d", "decode", 64, 8), mesh)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             specs["cache"])
        step = make_serve_step(cfg, mesh, donate_cache=False)
        nt, _ = step(params, jnp.ones((8, 1), jnp.int32), cache, jnp.int32(3))
        assert nt.shape == (8, 1)
    print("OK")
    """)
    assert "OK" in out


def test_median_mode_round_step():
    """Cross-client baseline mode: coordinate median across clients
    neutralizes a sign-flipping minority (and exists to quantify its
    N x exchange cost at scale — EXPERIMENTS.md §Perf)."""
    out = _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro import configs, models
    from repro.core.diversefl import DiverseFLConfig
    from repro.launch.train import make_fl_round_step
    from repro.sharding import partition_pytree

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = configs.get("gemma-2b", smoke=True)
    params = models.init(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), partition_pytree(params)))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (8, 64), 0, cfg.vocab_size)
    inputs = {
        "tokens": tokens,
        "guide_tokens": tokens.reshape(4, 2, 64)[:, :1],
        "byz_kind": jnp.array([0, 1, 0, 0], jnp.int32),
        "rng": jnp.zeros((2,), jnp.uint32),
    }
    step = make_fl_round_step(cfg, mesh, DiverseFLConfig(), lr=0.1,
                              donate=False, robust_mode="median")
    p, m = step(params, inputs)
    l0 = float(m["loss"])
    for _ in range(5):
        p, m = step(p, inputs)
    assert float(m["loss"]) < l0, (l0, float(m["loss"]))
    print("OK", l0, float(m["loss"]))
    """)
    assert "OK" in out


def test_dryrun_entrypoint_smoke():
    """The actual dryrun module (512 fake devices) on one cheap combo."""
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "h2o-danube-1.8b", "--shape", "decode_32k", "--mesh", "pod"],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "TF_CPP_MIN_LOG_LEVEL": "2"})
    assert p.returncode == 0, p.stderr[-4000:]
    assert "[ok  ]" in p.stdout
