import os

# Keep the default single CPU device for unit/smoke tests (the dry-run and
# the mesh integration tests set device counts in their own subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

import jax
jax.config.update("jax_enable_x64", False)
