"""Unit + property tests for the DiverseFL criteria (the paper's Eq. 2-6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (DiverseFLConfig, diversefl_aggregate, diversefl_mask,
                        guiding_update, masked_mean, similarity_stats,
                        similarity_stats_tree)

CFG = DiverseFLConfig()  # (0, 0.5, 2) — paper defaults


def test_benign_identical_update_passes():
    dot, zz, gg = similarity_stats(jnp.ones(64), jnp.ones(64))
    assert bool(diversefl_mask(dot, zz, gg, CFG))


def test_sign_flip_fails_condition1():
    z = -jnp.ones(64)
    g = jnp.ones(64)
    dot, zz, gg = similarity_stats(z, g)
    assert dot < 0
    assert not bool(diversefl_mask(dot, zz, gg, CFG))


def test_large_scale_fails_condition2():
    g = jnp.ones(64)
    for scale, keep in [(0.4, False), (0.6, True), (1.9, True), (2.1, False)]:
        dot, zz, gg = similarity_stats(scale * g, g)
        assert bool(diversefl_mask(dot, zz, gg, CFG)) == keep, scale


def test_same_value_attack_caught_by_direction_or_length():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=1000).astype(np.float32)) * 1e-3
    z = jnp.full((1000,), 1e4)
    dot, zz, gg = similarity_stats(z, g)
    assert not bool(diversefl_mask(dot, zz, gg, CFG))


@settings(max_examples=40, deadline=None)
@given(st.floats(0.51, 1.99), st.floats(-1.0, 1.0))
def test_mask_boundary_properties(scale, direction):
    """Within the C2 band, the mask is exactly the sign test on the dot."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=256).astype(np.float32))
    z = scale * (jnp.sign(jnp.float32(direction) + 1e-9) * g)
    dot, zz, gg = similarity_stats(z, g)
    keep = bool(diversefl_mask(dot, zz, gg, CFG))
    assert keep == (float(dot) > 0.0)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(0, 7))
def test_masked_mean_matches_numpy(n, drop):
    rng = np.random.default_rng(n * 13 + drop)
    u = rng.normal(size=(n, 5)).astype(np.float32)
    mask = np.ones(n, bool)
    mask[: min(drop, n - 1)] = False
    tree = {"a": jnp.asarray(u), "b": jnp.asarray(u[:, :2])}
    got = masked_mean(tree, jnp.asarray(mask))
    np.testing.assert_allclose(got["a"], u[mask].mean(0), rtol=1e-5, atol=1e-6)


def test_similarity_stats_tree_matches_flat():
    rng = np.random.default_rng(1)
    a = rng.normal(size=(4, 7)).astype(np.float32)
    b = rng.normal(size=(11,)).astype(np.float32)
    za = {"x": jnp.asarray(a), "y": jnp.asarray(b)}
    gb = {"x": jnp.asarray(a * 0.5), "y": jnp.asarray(b * 0.5)}
    dot, zz, gg = similarity_stats_tree(za, gb)
    flat_z = np.concatenate([a.ravel(), b])
    flat_g = flat_z * 0.5
    np.testing.assert_allclose(dot, flat_z @ flat_g, rtol=1e-5)
    np.testing.assert_allclose(zz, flat_z @ flat_z, rtol=1e-5)
    np.testing.assert_allclose(gg, flat_g @ flat_g, rtol=1e-5)


def test_guiding_update_is_E_sgd_steps():
    """Δ̃ must equal θ0 - θE for plain SGD on the guide sample."""
    params = {"w": jnp.ones((3,)), "b": jnp.zeros(())}

    def grad_fn(p, batch):
        x = batch
        return jax.grad(lambda q: jnp.sum((q["w"] * x + q["b"]) ** 2))(p)

    x = jnp.asarray([1.0, 2.0, 3.0])
    for E in (1, 3):
        delta = guiding_update(params, x, grad_fn, lr=0.01, E=E)
        theta = params
        for _ in range(E):
            g = grad_fn(theta, x)
            theta = jax.tree.map(lambda t, gg: t - 0.01 * gg, theta, g)
        want = jax.tree.map(lambda a, b: a - b, params, theta)
        np.testing.assert_allclose(delta["w"], want["w"], rtol=1e-5)
        np.testing.assert_allclose(delta["b"], want["b"], rtol=1e-5)


def test_diversefl_aggregate_end_to_end():
    """Stacked-client aggregate: byzantine rows flagged, mean over rest."""
    rng = np.random.default_rng(0)
    n, d = 6, 50
    g = rng.normal(size=(n, d)).astype(np.float32)
    z = g.copy()
    z[2] = -z[2]            # sign flip
    z[4] = z[4] * 10.0      # huge scale
    updates = {"w": jnp.asarray(z)}
    guides = {"w": jnp.asarray(g)}
    agg, mask, stats = diversefl_aggregate(updates, guides, CFG)
    assert list(np.asarray(mask)) == [True, True, False, True, False, True]
    np.testing.assert_allclose(
        agg["w"], z[[0, 1, 3, 5]].mean(0), rtol=1e-5, atol=1e-6)
