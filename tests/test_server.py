"""SecureServer + aggregator registry: completeness, equivalence to the
pre-refactor dispatch, and the enclave trust boundary (guides must be
computed from unsealed bytes only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as agg
from repro.core.diversefl import (DiverseFLConfig, diversefl_mask,
                                  guiding_update, masked_mean_flat,
                                  similarity_stats_matrix)
from repro.fl.server import (AggregationContext, SecureServer, aggregate,
                             available_aggregators, get_aggregator)

# the dispatch names the seed's if/elif chain supported
LEGACY_AGGREGATORS = ("diversefl", "oracle", "mean", "median", "trimmed_mean",
                      "krum", "bulyan", "resampling", "fltrust")


def _fixtures(n=9, d=40, f=2, seed=0):
    rng = np.random.default_rng(seed)
    U = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    root = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    byz = jnp.zeros((n,), bool).at[:f].set(True)
    key = jax.random.PRNGKey(7)
    ctx = AggregationContext(key=key, f=f, byz_mask=byz, guides=G,
                             root_update=root, resample_s=2)
    return U, G, root, byz, key, ctx


# ----------------------------------------------------------------------
# registry completeness + equivalence with the pre-refactor code paths
# ----------------------------------------------------------------------

def test_registry_resolves_every_legacy_name():
    for name in LEGACY_AGGREGATORS:
        entry = get_aggregator(name)
        assert entry.name == name and callable(entry.fn)
    assert set(LEGACY_AGGREGATORS) <= set(available_aggregators())


def test_registry_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown aggregator"):
        get_aggregator("nope")


def test_registry_matches_legacy_dispatch():
    """Each rule must produce the exact delta the seed's if/elif chain
    computed from the same inputs (fixed seed, same rng key)."""
    U, G, root, byz, key, ctx = _fixtures()
    dot, zz, gg = similarity_stats_matrix(U, G)
    mask = diversefl_mask(dot, zz, gg, ctx.dfl)
    expected = {
        "diversefl": agg.oracle_sgd(U, mask),
        "oracle": agg.oracle_sgd(U, ~byz),
        "mean": U.mean(0),
        "median": agg.median(U),
        "trimmed_mean": agg.trimmed_mean(U, ctx.f),
        "krum": agg.krum(U, ctx.f),
        "bulyan": agg.bulyan(U, ctx.f),
        "resampling": agg.resampling(U, key, ctx.resample_s),
        "fltrust": agg.fltrust(U, root),
    }
    for name, want in expected.items():
        delta, logs = aggregate(name, U, ctx)
        np.testing.assert_allclose(np.asarray(delta), np.asarray(want),
                                   rtol=1e-6, atol=1e-7, err_msg=name)
    # diversefl logs carry the criterion diagnostics
    _, logs = aggregate("diversefl", U, ctx)
    assert set(logs) >= {"mask", "c1", "c2", "c1c2"}
    np.testing.assert_array_equal(np.asarray(logs["mask"]), np.asarray(mask))


def test_diversefl_kernel_paths_agree_with_xla_path():
    """use_kernel_stats / use_kernel_agg route through Pallas (interpret
    mode on CPU) and must agree with the plain XLA path."""
    U, G, root, byz, key, ctx = _fixtures(n=5, d=300)
    base_delta, base_logs = aggregate("diversefl", U, ctx)
    for kw in ({"use_kernel_stats": True}, {"use_kernel_agg": True}):
        ctx_k = AggregationContext(key=key, f=ctx.f, byz_mask=byz, guides=G,
                                   root_update=root, **kw)
        delta, logs = aggregate("diversefl", U, ctx_k)
        np.testing.assert_allclose(np.asarray(delta), np.asarray(base_delta),
                                   rtol=1e-5, atol=1e-6, err_msg=str(kw))
        np.testing.assert_array_equal(np.asarray(logs["mask"]),
                                      np.asarray(base_logs["mask"]))


# ----------------------------------------------------------------------
# SecureServer trust boundary
# ----------------------------------------------------------------------

def _ingest(server, n_clients=3, s=4, d=6, seed=0):
    rng = np.random.default_rng(seed)
    for j in range(n_clients):
        server.ingest_samples(j, rng.normal(size=(s, d)).astype(np.float32),
                              rng.integers(0, 5, size=s).astype(np.int32))


def test_attestation_rejects_wrong_enclave_identity():
    from repro.core.tee import Enclave
    with pytest.raises(RuntimeError, match="attestation failed"):
        SecureServer(enclave=Enclave("evil-enclave"))


def test_guides_come_from_unsealed_bytes():
    """Tampering with the sealed blob must change the guide batch and the
    guiding update — proving the guide path reads through the enclave's
    sealed store, not a raw-sample side channel."""
    server = SecureServer()
    _ingest(server)
    gx1, gy1 = server.guide_batches()

    # flip the sealed *label* region of client 1's blob (stays valid int32)
    meta = server.enclave._meta[1]
    nx = 4 * int(np.prod(meta["x_shape"]))
    blob = bytearray(server.enclave._store[1])
    blob[nx:] = bytes(b ^ 0xFF for b in blob[nx:])
    server.enclave._store[1] = bytes(blob)

    gx2, gy2 = server.guide_batches(refresh=True)
    np.testing.assert_allclose(gx2[1], gx1[1])           # x region untouched
    assert np.asarray(gy2[1]).tobytes() != np.asarray(gy1[1]).tobytes()

    # the guiding update computed inside the enclave changes with it
    params = {"w": jnp.ones((6, 1))}

    def grad_fn(p, batch):
        x, y = batch
        tgt = y.astype(jnp.float32)[:, None]
        return jax.grad(lambda q: jnp.mean((x @ q["w"] - tgt) ** 2))(p)

    d1 = guiding_update(params, (gx1[1], gy1[1]), grad_fn, lr=0.1, E=1)
    d2 = guiding_update(params, (gx2[1], gy2[1]), grad_fn, lr=0.1, E=1)
    assert not np.allclose(np.asarray(d1["w"]), np.asarray(d2["w"]))


def test_guide_cache_invalidated_by_reseal():
    """Re-sealing through the enclave (as the sample-poisoning tests do)
    must be visible on the next guide_batches() call without an explicit
    refresh — the cache is keyed on the enclave's seal version."""
    server = SecureServer()
    _ingest(server)
    _, gy1 = server.guide_batches()
    x, y = server.enclave.unseal_samples(0)
    server.enclave.seal_samples(0, x, 4 - y)
    _, gy2 = server.guide_batches()
    np.testing.assert_array_equal(np.asarray(gy2[0]), 4 - np.asarray(gy1[0]))


def test_guide_batches_stay_id_aligned_after_drop():
    """Sec. IV-C: dropping a screened-out client must not shift the rows
    of other clients' guide batches, and the dropped id's zero guide can
    never pass the C1/C2 criterion."""
    server = SecureServer()
    _ingest(server, n_clients=5)
    gx_before, _ = server.guide_batches()
    server.drop_client(2)
    gx_after, _ = server.guide_batches()
    assert gx_after.shape == gx_before.shape
    for j in (0, 1, 3, 4):
        np.testing.assert_allclose(gx_after[j], gx_before[j], err_msg=str(j))
    np.testing.assert_array_equal(np.asarray(gx_after[2]), 0.0)
    # zero guide -> dot=0, ||g||²=0 -> rejected by the criterion
    assert not bool(diversefl_mask(jnp.float32(0.0), jnp.float32(1.0),
                                   jnp.float32(0.0), DiverseFLConfig()))


def test_guide_batches_empty_store_raises():
    server = SecureServer()
    with pytest.raises(RuntimeError, match="no sealed samples"):
        server.guide_batches()


def test_compute_guides_matches_direct_guiding_update():
    server = SecureServer()
    _ingest(server)
    gx, gy = server.guide_batches()
    params = {"w": jnp.full((6, 1), 0.5)}

    def grad_fn(p, batch):
        x, y = batch
        tgt = y.astype(jnp.float32)[:, None]
        return jax.grad(lambda q: jnp.mean((x @ q["w"] - tgt) ** 2))(p)

    guides = server.compute_guides(params, grad_fn, lr=0.05, E=2)
    for j in range(3):
        want = guiding_update(params, (gx[j], gy[j]), grad_fn, lr=0.05, E=2)
        np.testing.assert_allclose(guides["w"][j], want["w"], rtol=1e-6)


def test_oracle_and_diversefl_share_masked_mean():
    """One source of truth for Eq. 6: the registry's masked aggregation is
    core.diversefl.masked_mean_flat."""
    U, G, root, byz, key, ctx = _fixtures()
    delta, _ = aggregate("oracle", U, ctx)
    np.testing.assert_allclose(np.asarray(delta),
                               np.asarray(masked_mean_flat(U, ~byz)),
                               rtol=1e-6)
