"""launch.shapes / benchmarks.analytic: spec construction and the
analytic roofline model (no device allocation, single-CPU safe)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch.shapes import SHAPES, applicable, InputShape


def test_shape_registry():
    assert SHAPES["train_4k"].seq == 4096 and SHAPES["train_4k"].batch == 256
    assert SHAPES["prefill_32k"].seq == 32768 and SHAPES["prefill_32k"].batch == 32
    assert SHAPES["decode_32k"].batch == 128
    assert SHAPES["long_500k"].seq == 524288 and SHAPES["long_500k"].batch == 1


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    capable = {a for a in configs.all_arch_ids()
               if applicable(configs.get(a), long)}
    assert capable == {"h2o-danube-1-8b", "jamba-v0-1-52b",
                       "falcon-mamba-7b"}
    # every arch runs the other three shapes
    for a in configs.all_arch_ids():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert applicable(configs.get(a), SHAPES[s])


def test_analytic_model_flops_sane():
    from benchmarks.analytic import model_flops
    # training costs ~3x prefill per token; decode per-token cost is tiny
    tr = model_flops("gemma-2b", "train_4k")
    pf = model_flops("gemma-2b", "prefill_32k")
    dc = model_flops("gemma-2b", "decode_32k")
    tokens_tr = 256 * 4096 * (1 + 16 / 256)   # + guide fraction
    tokens_pf = 32 * 32768
    assert tr / tokens_tr > 2.5 * (pf / tokens_pf) * 0.5
    assert dc < pf / 1000
    # MoE uses active params: kimi train flops ~ active(32.5B), not 1T
    kt = model_flops("kimi-k2-1t-a32b", "train_4k")
    assert kt < 6 * 80e9 * tokens_tr * 3      # way below total-param cost
    assert kt > 6 * 20e9 * tokens_tr          # above a 20B dense


def test_mamba_decode_is_context_free():
    from benchmarks.analytic import model_flops
    d32 = model_flops("falcon-mamba-7b", "decode_32k")
    d500 = model_flops("falcon-mamba-7b", "long_500k")
    # batch 128 vs 1: per-sequence decode cost identical (state space)
    assert abs(d32 / 128 - d500) / d500 < 0.01
