"""Compressed update streams (ISSUE 7): codec contract, error feedback,
the fused dequantize-and-fold kernel, and the end-to-end guarantees.

Pinned here, per DESIGN.md §10:

* codec roundtrip error bounds — f32 bitwise, bf16 half-ULP relative,
  int8 absmax_block/254 per block — and the measured wire sizes;
* ``dequant_fold_update`` (Pallas, interpret on CPU) bitwise against
  ``kernels/ref.dequant_fold_ref``, the one decode definition;
* error feedback: the residual is exactly the compression error, and
  the accumulated transmitted signal tracks the true signal with error
  bounded by one round's quantization error (EF-SGD's telescoping);
* ``compression="f32"`` training is bitwise-equal to the dense
  uncompressed fold at every (chunk, shards, pods) combination;
* lossy codecs: streaming == dense bitwise (same encoded bits folded
  either way), sweep == solo bitwise with a structural compression
  axis, and diversefl accuracy within a point of uncompressed on the
  paper-style N=256 grid;
* the launch-side knobs route through the same registry:
  ``resolve_update_dtype`` and the pinned XLA:CPU AllReducePromotion
  workaround (``update_psum_dtype``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.data import FederatedData, make_classification
from repro.data.partition import partition_sorted_shards
from repro.fl import (FLConfig, Federation, SweepSpec, run_federated_sweep,
                      run_federated_training, structural_key)
from repro.fl.compression import (QBLOCK, available_codecs,
                                  encode_with_feedback, get_codec,
                                  quantize_tree, wire_bytes)
from repro.fl.small_models import softmax_regression
from repro.kernels import ops
from repro.kernels.ref import dequant_fold_ref, dequant_int8_ref
from repro.launch.train import resolve_update_dtype, update_psum_dtype
from repro.optim import inv_sqrt_lr

N, F, DIM, NC = 23, 5, 8, 4
FED_KEY = jax.random.PRNGKey(2)


@pytest.fixture(scope="module")
def fed_data():
    x, y = make_classification(jax.random.PRNGKey(0), N * 16, NC, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N), NC)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, NC, DIM)
    return softmax_regression(input_dim=DIM, n_classes=NC), data, tx, ty


def _cfg(**kw):
    kw.setdefault("n_clients", N)
    kw.setdefault("f", F)
    kw.setdefault("rounds", 4)
    kw.setdefault("eval_every", 2)
    kw.setdefault("batch_size", 4)
    kw.setdefault("l2", 0.0)
    kw.setdefault("aggregator", "diversefl")
    kw.setdefault("attack", AttackConfig(kind="sign_flip"))
    return FLConfig(**kw)


def _train(fed_data, cfg):
    model, data, tx, ty = fed_data
    fed = Federation.create(model, data, tx, ty, cfg, FED_KEY)
    return run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def _assert_hist_bitwise(a, b, label):
    assert np.array_equal(_flat(a["params"]), _flat(b["params"])), \
        f"{label}: final params differ"
    assert set(a) == set(b), f"{label}: history keys differ"
    for k in a:
        if k != "params":
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), \
                f"{label}: history[{k!r}] differs"


# ----------------------------------------------------------------------
# codec registry + roundtrip error bounds
# ----------------------------------------------------------------------

def test_registry_names_and_unknown():
    assert {"f32", "bf16", "int8"} <= set(available_codecs())
    with pytest.raises(ValueError, match="unknown compression codec"):
        get_codec("zstd")
    with pytest.raises(ValueError, match="f32"):
        get_codec("zstd")        # the error lists what IS available


def test_f32_roundtrip_bitwise():
    codec = get_codec("f32")
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(5, 37)).astype(np.float32))
    assert codec.lossless
    assert np.array_equal(np.asarray(codec.decode(codec.encode(x))),
                          np.asarray(x))


def test_bf16_half_ulp_bound():
    codec = get_codec("bf16")
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(4, 301)).astype(np.float32))
    err = np.abs(np.asarray(codec.decode(codec.encode(x)) - x))
    # round-to-nearest-even bf16: relative error <= 2^-8 (half ULP)
    assert np.all(err <= 2.0 ** -8 * np.abs(np.asarray(x)) + 1e-30)


def test_int8_per_block_bound_and_shapes():
    codec = get_codec("int8")
    d = 2 * QBLOCK + 10                      # exercises the padded tail
    x = np.random.default_rng(2).normal(size=(3, d)).astype(np.float32)
    x[1, :QBLOCK] = 0.0                      # an all-zero block
    enc = codec.encode(jnp.asarray(x))
    assert enc["q"].dtype == jnp.int8 and enc["q"].shape == x.shape
    assert enc["scale"].shape == (3, -(-d // QBLOCK))
    dec = np.asarray(codec.decode(enc))
    assert np.array_equal(dec[1, :QBLOCK], np.zeros(QBLOCK))
    err = np.abs(dec - x)
    for b in range(-(-d // QBLOCK)):
        blk = slice(b * QBLOCK, min((b + 1) * QBLOCK, d))
        bound = np.abs(x[:, blk]).max(axis=1) / 254.0
        assert np.all(err[:, blk] <= bound[:, None] * (1 + 1e-6) + 1e-12)


def test_int8_decode_is_the_shared_ref():
    codec = get_codec("int8")
    x = jnp.asarray(np.random.default_rng(3).normal(
        size=(2, 70)).astype(np.float32))
    enc = codec.encode(x)
    assert np.array_equal(
        np.asarray(codec.decode(enc)),
        np.asarray(dequant_int8_ref(enc["q"], enc["scale"], QBLOCK)))


def test_wire_bytes_measured():
    d = 333
    assert wire_bytes(get_codec("f32"), d) == 4 * d
    assert wire_bytes(get_codec("bf16"), d) == 2 * d
    assert wire_bytes(get_codec("int8"), d) == d + 4 * (-(-d // QBLOCK))
    # the headline number: int8 at mlp scale is >= 3.5x under dense f32
    assert 4 * 50698 / wire_bytes(get_codec("int8"), 50698) > 3.5


# ----------------------------------------------------------------------
# error feedback
# ----------------------------------------------------------------------

@pytest.mark.parametrize("name", ["bf16", "int8"])
def test_encode_with_feedback_residual_is_the_error(name):
    codec = get_codec(name)
    rng = np.random.default_rng(4)
    u = jnp.asarray(rng.normal(size=(6, 2 * QBLOCK)).astype(np.float32))
    r = jnp.asarray(rng.normal(size=(6, 2 * QBLOCK)).astype(np.float32))
    enc, dec, new_r = encode_with_feedback(codec, u, r)
    v = np.asarray(u) + np.asarray(r)
    assert np.array_equal(np.asarray(dec), np.asarray(codec.decode(enc)))
    assert np.allclose(np.asarray(dec) + np.asarray(new_r), v,
                       rtol=0, atol=1e-6)


def test_f32_feedback_is_identity():
    codec = get_codec("f32")
    u = jnp.asarray(np.random.default_rng(5).normal(
        size=(3, 50)).astype(np.float32))
    enc, dec, new_r = encode_with_feedback(codec, u, jnp.zeros_like(u))
    assert np.array_equal(np.asarray(dec), np.asarray(u))
    assert not np.asarray(new_r).any()


def test_error_feedback_telescopes():
    """EF-SGD's point: sum_t dec_t = sum_t u_t − resid_T, so the
    accumulated transmitted signal is off by ONE round's compression
    error, not T of them.  Without feedback the bias grows with T."""
    codec = get_codec("int8")
    rng = np.random.default_rng(6)
    u = jnp.asarray(rng.normal(size=(QBLOCK,)).astype(np.float32))
    T = 20
    resid = jnp.zeros_like(u)
    acc_ef = np.zeros(u.shape, np.float64)
    acc_no = np.zeros(u.shape, np.float64)
    for _ in range(T):
        _, dec, resid = encode_with_feedback(codec, u, resid)
        acc_ef += np.asarray(dec)
        acc_no += np.asarray(codec.decode(codec.encode(u)))
    true = T * np.asarray(u, np.float64)
    one_round = np.abs(np.asarray(u)).max() / 254.0
    assert np.abs(acc_ef - true).max() <= one_round * (1 + 1e-4) + 1e-6
    # the no-feedback bias is the same deterministic error T times over
    assert np.abs(acc_no - true).max() >= np.abs(acc_ef - true).max()


def test_quantize_tree_lossless_is_identity_lossy_rounds():
    tree = {"w": jnp.asarray(np.random.default_rng(7).normal(
        size=(4, 3, 5)).astype(np.float32))}
    assert quantize_tree(get_codec("f32"), tree) is tree
    out = quantize_tree(get_codec("int8"), tree)
    assert out["w"].shape == tree["w"].shape
    assert not np.array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    ref = get_codec("int8")
    flat = tree["w"].reshape((4, -1))
    assert np.array_equal(
        np.asarray(out["w"]),
        np.asarray(ref.decode(ref.encode(flat)).reshape(tree["w"].shape)))


# ----------------------------------------------------------------------
# the fused dequantize-and-fold kernel vs the reference decoder
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n,d,qblock", [(5, 40, 16), (7, 300, 128),
                                        (16, 2 * QBLOCK, QBLOCK)])
def test_dequant_fold_kernel_matches_ref_bitwise(n, d, qblock):
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.integers(-127, 128, size=(n, d)).astype(np.int8))
    scale = jnp.asarray(
        rng.uniform(0, 0.1, size=(n, -(-d // qblock))).astype(np.float32))
    w = jnp.asarray((rng.random(n) < 0.7).astype(np.float32)
                    * rng.uniform(0, 2, n).astype(np.float32))
    acc = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    got = ops.dequant_fold_update(q, scale, w, acc, qblock=qblock)
    want = dequant_fold_ref(q, scale, w, acc, qblock)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_dequant_fold_kernel_chunked_matches_ref():
    n, d, qblock = 4, 5 * 64, 64
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.integers(-127, 128, size=(n, d)).astype(np.int8))
    scale = jnp.asarray(
        rng.uniform(0, 0.1, size=(n, d // qblock)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    acc = jnp.zeros((d,), jnp.float32)
    got = ops.dequant_fold_update(q, scale, w, acc, qblock=qblock,
                                  chunk=2 * qblock)       # multi-tile grid
    want = dequant_fold_ref(q, scale, w, acc, qblock)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------------------
# FLConfig validation + launch-side dtype routing
# ----------------------------------------------------------------------

def test_config_unknown_codec_raises():
    with pytest.raises(ValueError, match="not a registered codec"):
        _cfg(compression="gzip")


def test_config_lossy_kernel_agg_requires_streaming():
    with pytest.raises(ValueError, match="requires streaming=True"):
        _cfg(compression="int8", use_kernel_agg=True, streaming=False)
    _cfg(compression="int8", use_kernel_agg=True, streaming=True)
    _cfg(compression="f32", use_kernel_agg=True, streaming=False)


def test_update_psum_dtype_cpu_promotion_pin():
    """XLA:CPU AllReducePromotion CHECK-fails on a bf16 all-reduce; the
    workaround (psum in f32 on the cpu backend) must stay until the
    backend fixes it.  If this test fails because jax started accepting
    bf16 psums on CPU, the gate in launch/train.py can go."""
    assert jax.default_backend() == "cpu"
    assert update_psum_dtype(jnp.bfloat16) == jnp.float32
    assert update_psum_dtype(jnp.float32) == jnp.float32


def test_resolve_update_dtype_routes_through_registry():
    assert resolve_update_dtype("f32") == jnp.float32
    assert resolve_update_dtype("bf16") == jnp.bfloat16
    # legacy knob still honored when compression is defaulted
    assert resolve_update_dtype("f32", jnp.bfloat16) == jnp.bfloat16
    with pytest.raises(ValueError, match="no dense wire dtype"):
        resolve_update_dtype("int8")
    with pytest.raises(ValueError, match="conflicts"):
        resolve_update_dtype("bf16", jnp.float32)


# ----------------------------------------------------------------------
# end-to-end: f32 bitwise at (chunk, shards, pods); lossy contracts
# ----------------------------------------------------------------------

@pytest.mark.parametrize("chunk,shards,pods", [
    (8, None, None), (4, None, None), (8, 1, 1)])
def test_f32_streaming_bitwise_vs_dense_grid(fed_data, chunk, shards, pods):
    """The lossless passthrough must reproduce the pre-compression fold
    bit for bit at every sequential fold partition — compression="f32"
    skips the error-feedback carry structurally, so the jaxpr is the
    PR-6 one (chunking and S=1/P=1 never reassociate)."""
    dense = _train(fed_data, _cfg(streaming=False))
    strm = _train(fed_data, _cfg(streaming=True, compression="f32",
                                 client_chunk=chunk, stream_shards=shards,
                                 pods=pods))
    _assert_hist_bitwise(strm, dense, f"chunk={chunk},shards={shards},"
                                      f"pods={pods}")


@pytest.mark.parametrize("chunk,shards,pods", [(8, 3, None), (4, 2, 2)])
def test_f32_streaming_sharded_grid_close(fed_data, chunk, shards, pods):
    """Sharded/two-tier partitions reassociate the merge (the PR-6
    contract: per-client criterion stats bitwise, delta to tight fp
    tolerance) — the f32 codec must inherit exactly that, no worse."""
    dense = _train(fed_data, _cfg(streaming=False))
    strm = _train(fed_data, _cfg(streaming=True, compression="f32",
                                 client_chunk=chunk, stream_shards=shards,
                                 pods=pods))
    assert np.array_equal(np.asarray(strm["mask_tpr"]),
                          np.asarray(dense["mask_tpr"]))
    assert np.array_equal(np.asarray(strm["mask_fpr"]),
                          np.asarray(dense["mask_fpr"]))
    np.testing.assert_allclose(_flat(strm["params"]),
                               _flat(dense["params"]),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("name", ["bf16", "int8"])
def test_lossy_streaming_matches_dense_bitwise(fed_data, name):
    """Same encoded bits folded streaming or dense must agree exactly:
    both sides decode through the one reference decoder."""
    dense = _train(fed_data, _cfg(compression=name, streaming=False))
    strm = _train(fed_data, _cfg(compression=name, streaming=True,
                                 client_chunk=8))
    _assert_hist_bitwise(strm, dense, f"{name} streaming-vs-dense")


def test_lossy_kernel_agg_matches_jnp_fold(fed_data):
    """use_kernel_agg routes int8 blocks through the Pallas
    dequantize-and-fold kernel; the fold must agree with the jnp path
    to fp tolerance (the kernel reassociates the row sum)."""
    plain = _train(fed_data, _cfg(compression="int8", streaming=True,
                                  client_chunk=8))
    kern = _train(fed_data, _cfg(compression="int8", streaming=True,
                                 client_chunk=8, use_kernel_agg=True))
    assert np.allclose(_flat(kern["params"]), _flat(plain["params"]),
                       rtol=1e-5, atol=1e-6)


def test_comm_stats_in_history(fed_data):
    hist = _train(fed_data, _cfg(compression="int8"))
    d = _flat(hist["params"]).size
    assert hist["uplink_bytes_per_client"] == \
        d + 4 * (-(-d // QBLOCK))
    assert hist["dense_uplink_bytes_per_round"] == \
        hist["downlink_bytes_per_round"] == 4 * d * N
    assert hist["uplink_reduction"] > 3.5
    f32 = _train(fed_data, _cfg())
    assert f32["uplink_reduction"] == 1.0
    assert f32["uplink_bytes_per_round"] == 4 * d * N


# ----------------------------------------------------------------------
# sweep: structural compression axis, sweep == solo, accuracy grid
# ----------------------------------------------------------------------

def test_compression_axis_is_structural():
    a = _cfg(compression="f32")
    b = _cfg(compression="int8")
    assert structural_key(a) != structural_key(b)


def test_sweep_compressions_axis_bitwise_vs_solo(fed_data):
    model, data, tx, ty = fed_data
    base = _cfg(rounds=2, eval_every=2)
    spec = SweepSpec(base=base, seeds=(0, 1),
                     compressions=("f32", "int8"))
    cells = spec.cells()
    assert sorted({c.cfg.compression for c in cells}) == ["f32", "int8"]
    fed = Federation.create(model, data, tx, ty, base, FED_KEY)
    hists = run_federated_sweep(model, fed, spec, inv_sqrt_lr(0.05))
    for cell, hist in zip(cells, hists):
        solo = _train(fed_data, cell.cfg)
        _assert_hist_bitwise(hist, solo,
                             f"compression={cell.cfg.compression},"
                             f"seed={cell.cfg.seed}")


def test_accuracy_within_a_point_n256():
    """The paper-style N=256 diversefl grid under sign_flip: bf16 and
    int8 with error feedback must land within one accuracy point of
    the uncompressed run (the EF convergence guarantee, measured)."""
    n, per_client = 256, 8
    x, y = make_classification(jax.random.PRNGKey(0), n * per_client,
                               NC, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, n), NC)
    tx, ty = make_classification(jax.random.PRNGKey(9), 256, NC, DIM)
    model = softmax_regression(input_dim=DIM, n_classes=NC)
    base = FLConfig(n_clients=n, f=n // 5, rounds=16, eval_every=16,
                    batch_size=2, l2=0.0, aggregator="diversefl",
                    attack=AttackConfig(kind="sign_flip"))
    fed = Federation.create(model, data, tx, ty, base, FED_KEY)
    spec = SweepSpec(base=base, compressions=("f32", "bf16", "int8"))
    hists = run_federated_sweep(model, fed, spec, inv_sqrt_lr(0.05))
    acc = {cell.cfg.compression: float(np.asarray(h["acc"])[-1])
           for cell, h in zip(spec.cells(), hists)}
    assert acc["f32"] > 0.5, f"uncompressed baseline failed: {acc}"
    for name in ("bf16", "int8"):
        assert abs(acc[name] - acc["f32"]) <= 0.01 + 1e-9, \
            f"{name} accuracy {acc[name]:.4f} vs f32 {acc['f32']:.4f}"
