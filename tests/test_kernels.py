"""Per-kernel correctness: shape/dtype sweeps against the pure-jnp oracles
(interpret mode executes the same kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref


# ----------------------------------------------------------------------
# similarity
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n,d,chunk,dtype", [
    (1, 128, 128, jnp.float32),
    (5, 1000, 256, jnp.float32),      # pad path
    (8, 4096, 1024, jnp.bfloat16),
    (3, 70, 512, jnp.float32),        # d < chunk
])
def test_similarity_shapes(n, d, chunk, dtype):
    rng = np.random.default_rng(d)
    z = jnp.asarray(rng.normal(size=(n, d))).astype(dtype)
    g = jnp.asarray(rng.normal(size=(n, d))).astype(dtype)
    got = ops.similarity_stats(z, g, chunk=chunk)
    want = ref.similarity_ref(z, g)
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 9), st.integers(1, 600))
def test_similarity_property(n, d):
    rng = np.random.default_rng(n * 1000 + d)
    z = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    got = ops.similarity_stats(z, g, chunk=128)
    want = ref.similarity_ref(z, g)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # norms are non-negative; Cauchy-Schwarz holds
    assert (np.asarray(got[:, 1]) >= 0).all()
    assert (got[:, 0] ** 2 <= got[:, 1] * got[:, 2] * (1 + 1e-4) + 1e-5).all()


# ----------------------------------------------------------------------
# masked aggregation (fused Step 5 / Eq. 6)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n,d,chunk,dtype", [
    (1, 128, 128, jnp.float32),
    (5, 1000, 256, jnp.float32),      # pad path
    (8, 4096, 1024, jnp.bfloat16),
    (3, 70, 512, jnp.float32),        # d < chunk
    (23, 2048, 512, jnp.float32),     # paper-scale client count
])
def test_masked_agg_matches_oracle_sgd(n, d, chunk, dtype):
    """Kernel parity with the aggregators.oracle_sgd reference (the same
    masked mean DiverseFL applies to the surviving updates)."""
    from repro.core import aggregators as agg
    rng = np.random.default_rng(d + n)
    u = jnp.asarray(rng.normal(size=(n, d))).astype(dtype)
    mask = jnp.asarray(rng.integers(0, 2, size=n).astype(bool))
    got = ops.masked_aggregate(u, mask, chunk=chunk)
    want = agg.oracle_sgd(u.astype(jnp.float32), mask)
    np.testing.assert_allclose(got, want,
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-6)


def test_masked_agg_empty_mask_yields_zero():
    u = jnp.ones((4, 300))
    got = ops.masked_aggregate(u, jnp.zeros((4,), bool))
    np.testing.assert_allclose(got, np.zeros(300))


def test_diversefl_step45_fused_matches_reference():
    """The two-HBM-pass fused path (similarity kernel -> mask -> masked-agg
    kernel) must reproduce the unfused XLA Step 4+5 exactly."""
    from repro.core.diversefl import DiverseFLConfig, diversefl_mask
    rng = np.random.default_rng(0)
    n, d = 9, 700
    g = rng.normal(size=(n, d)).astype(np.float32)
    z = g.copy()
    z[2] = -z[2]              # sign flip -> fails C1
    z[5] = z[5] * 10.0        # huge scale -> fails C2
    z, g = jnp.asarray(z), jnp.asarray(g)
    cfg = DiverseFLConfig()
    delta, mask, (dot, zz, gg) = ops.diversefl_step45(z, g, cfg, chunk=256)
    s = ref.similarity_ref(z, g)
    want_mask = diversefl_mask(s[:, 0], s[:, 1], s[:, 2], cfg)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(want_mask))
    np.testing.assert_allclose(delta, ref.masked_agg_ref(z, want_mask),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(jnp.stack([dot, zz, gg], -1), s, rtol=1e-5)


# ----------------------------------------------------------------------
# robust aggregation
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n,d,f", [(3, 256, 0), (9, 1000, 2), (23, 4096, 5),
                                   (8, 100, 3)])
def test_robust_agg_shapes(n, d, f):
    rng = np.random.default_rng(n + d)
    u = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    med, trim = ops.robust_aggregate(u, f=f, chunk=512)
    np.testing.assert_allclose(med, ref.median_ref(u), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(trim, ref.trimmed_ref(u, f), rtol=1e-5, atol=1e-6)


def test_robust_agg_tolerates_outliers():
    """Median ignores f huge rows (the Byzantine resilience property)."""
    rng = np.random.default_rng(0)
    u = rng.normal(size=(9, 300)).astype(np.float32)
    u[0] = 1e8
    u[5] = -1e8
    med, trim = ops.robust_aggregate(jnp.asarray(u), f=2)
    clean_med = np.median(u[[1, 2, 3, 4, 6, 7, 8]], axis=0)
    assert np.abs(np.asarray(med)).max() < 1e3
    assert np.abs(np.asarray(trim)).max() < 1e3


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,S,dh,window,bq,bk", [
    (1, 2, 2, 128, 32, None, 64, 64),
    (2, 4, 2, 192, 64, None, 64, 64),      # GQA + pad (192 % 64 == 0)
    (1, 4, 1, 256, 64, None, 128, 128),    # MQA
    (2, 2, 2, 256, 32, 64, 64, 64),        # sliding window
    (1, 2, 2, 100, 32, 32, 32, 32),        # pad path with window
])
def test_flash_attention(B, H, K, S, dh, window, bq, bk):
    rng = np.random.default_rng(S)
    q = jnp.asarray(rng.normal(size=(B, H, S, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, K, S, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, K, S, dh)).astype(np.float32))
    got = ops.flash_attention_bhsd(q, k, v, window=window, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    got = ops.flash_attention_bhsd(q, k, v, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@settings(max_examples=8, deadline=None)
@given(st.integers(33, 160), st.sampled_from([None, 16, 48]))
def test_flash_attention_property(S, window):
    rng = np.random.default_rng(S)
    q = jnp.asarray(rng.normal(size=(1, 2, S, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, S, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, S, 32)).astype(np.float32))
    got = ops.flash_attention_bhsd(q, k, v, window=window, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)


# ----------------------------------------------------------------------
# mamba scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,S,di,n,bs,bd", [
    (1, 64, 32, 8, 32, 32),
    (2, 256, 64, 16, 64, 32),
    (1, 128, 128, 4, 128, 128),
])
def test_mamba_scan(B, S, di, n, bs, bd):
    rng = np.random.default_rng(S + di)
    da = jnp.asarray(np.exp(-np.abs(rng.normal(size=(B, S, di, n)))).astype(np.float32))
    dbx = jnp.asarray(rng.normal(size=(B, S, di, n)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(B, S, n)).astype(np.float32))
    got = ops.mamba_scan_raw(da, dbx, c, bs=bs, bd=bd)
    want = ref.mamba_scan_ref(da, dbx, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_mamba_scan_state_carries_across_chunks():
    """A single impulse at t=0 must decay across chunk boundaries."""
    B, S, di, n = 1, 128, 8, 4
    da = jnp.full((B, S, di, n), 0.9, jnp.float32)
    dbx = jnp.zeros((B, S, di, n)).at[:, 0].set(1.0)
    c = jnp.ones((B, S, n), jnp.float32)
    y = ops.mamba_scan_raw(da, dbx, c, bs=32, bd=8)
    want = n * 0.9 ** np.arange(S)  # h decays geometrically, y = sum over n
    np.testing.assert_allclose(y[0, :, 0], want, rtol=1e-3)
