"""Batched experiment sweeps (ISSUE 5): structural grouping, the
bitwise sweep == solo contract, and the compile-count economics.

The acceptance grid: for every cell of a smoke grid covering
gaussian/sign_flip/label_flip/backdoor x all four streaming-family
aggregators x 2 seeds with partial participation, the batched sweep's
per-cell metric history and final params must be bitwise-equal to
running that cell solo through ``run_federated_training`` — and a
structural group must compile exactly once.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import AttackConfig, make_byzantine_mask
from repro.data import FederatedData, make_classification
from repro.data.partition import partition_sorted_shards
from repro.fl import (FLConfig, Federation, RoundEngine, SweepSpec,
                      group_cells, run_federated_sweep,
                      run_federated_training, structural_key, trace_counter)
from repro.fl.small_models import softmax_regression
from repro.optim import inv_sqrt_lr

N, F, DIM, NC = 23, 5, 8, 4
FED_KEY = jax.random.PRNGKey(2)

ATTACKS = (AttackConfig(kind="gaussian", sigma=1e4),
           AttackConfig(kind="sign_flip"),
           AttackConfig(kind="label_flip"),
           AttackConfig(kind="backdoor", source_class=1, target_class=2))
STREAM_FAMILY = ("diversefl", "oracle", "mean", "fltrust")


@pytest.fixture(scope="module")
def fed_data():
    x, y = make_classification(jax.random.PRNGKey(0), N * 16, NC, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N), NC)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, NC, DIM)
    return softmax_regression(input_dim=DIM, n_classes=NC), data, tx, ty


def _base(**kw):
    kw.setdefault("n_clients", N)
    kw.setdefault("f", F)
    kw.setdefault("rounds", 4)
    kw.setdefault("eval_every", 2)
    kw.setdefault("batch_size", 4)
    return FLConfig(**kw)


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def _solo(model, data, tx, ty, cfg, sched=None):
    """The reference: one federation per cell (same federation key the
    shared sweep federation was created with), one solo training run."""
    fed = Federation.create(model, data, tx, ty, cfg, FED_KEY)
    return run_federated_training(model, fed, cfg, sched or inv_sqrt_lr(0.05))


def _assert_cell_bitwise(hist, solo, label):
    assert np.array_equal(_flat(hist["params"]), _flat(solo["params"])), \
        f"{label}: final params differ"
    for k in solo:
        if k == "params":
            continue
        assert np.array_equal(np.asarray(hist[k]), np.asarray(solo[k])), \
            f"{label}: history[{k!r}] differs"
    assert set(hist) == set(solo), f"{label}: history keys differ"


# ----------------------------------------------------------------------
# the acceptance grid: sweep == solo, bitwise, every cell
# ----------------------------------------------------------------------

def test_smoke_grid_bitwise_equals_solo(fed_data):
    model, data, tx, ty = fed_data
    base = _base(participation=0.6)          # partial participation: C=14
    spec = SweepSpec(base=base, seeds=(0, 1), aggregators=STREAM_FAMILY,
                     attacks=ATTACKS)
    cells = spec.cells()
    assert len(cells) == 4 * 4 * 2
    assert len(group_cells(cells)) == 16     # attack x aggregator
    fed = Federation.create(model, data, tx, ty, base, FED_KEY)
    with trace_counter() as tc:
        results = run_federated_sweep(model, fed, spec, inv_sqrt_lr(0.05))
    delta = tc.snapshot()
    assert delta["training"] == 16           # exactly one compile per group
    assert delta["segment"] == 0 and delta["eval"] == 0
    for cell, hist in zip(cells, results):
        solo = _solo(model, data, tx, ty, cell.cfg)
        _assert_cell_bitwise(
            hist, solo,
            f"{cell.cfg.aggregator}/{cell.cfg.attack.kind}/s{cell.cfg.seed}")


def test_f_axis_batches_with_explicit_mask(fed_data):
    """Byzantine counts and explicit masks are scenario data: one group,
    each cell bitwise-equal to its solo twin (solo derives the same
    deterministic mask from f; the explicit-mask cell pins identities)."""
    model, data, tx, ty = fed_data
    base = _base(aggregator="diversefl",
                 attack=AttackConfig(kind="sign_flip"))
    custom = make_byzantine_mask(N, 3, key=jax.random.PRNGKey(11))
    spec = SweepSpec(base=base, seeds=(0,), fs=(0, F, custom))
    cells = spec.cells()
    assert len(group_cells(cells)) == 1
    fed = Federation.create(model, data, tx, ty, base, FED_KEY)
    results = run_federated_sweep(model, fed, spec, inv_sqrt_lr(0.05))
    for cell, hist in zip(cells[:2], results[:2]):   # int-f cells: solo twin
        _assert_cell_bitwise(hist, _solo(model, data, tx, ty, cell.cfg),
                             f"f={cell.cfg.f}")
    # the explicit-mask cell: solo reference with the mask installed
    fed3 = Federation.create(model, data, tx, ty, cells[2].cfg, FED_KEY)
    fed3.byz_mask = jnp.asarray(custom, bool)
    solo3 = run_federated_training(model, fed3, cells[2].cfg,
                                   inv_sqrt_lr(0.05))
    _assert_cell_bitwise(results[2], solo3, "explicit mask")


def test_lr_schedule_axis_and_partial_tail(fed_data):
    """Per-cell lr vectors batch; rounds % eval_every != 0 exercises the
    vmapped tail segment + eval row, still bitwise per cell."""
    model, data, tx, ty = fed_data
    base = _base(aggregator="mean", rounds=5, eval_every=2,
                 attack=AttackConfig(kind="none"))
    scheds = (inv_sqrt_lr(0.05), inv_sqrt_lr(0.2))
    spec = SweepSpec(base=base, seeds=(3,), lr_schedules=scheds)
    cells = spec.cells()
    assert len(group_cells(cells)) == 1
    fed = Federation.create(model, data, tx, ty, base, FED_KEY)
    results = run_federated_sweep(model, fed, spec)
    for cell, hist, sched in zip(cells, results, scheds):
        _assert_cell_bitwise(hist, _solo(model, data, tx, ty, cell.cfg,
                                         sched), "lr axis")
        assert hist["round"] == [2, 4, 5]


def test_streaming_sweep_bitwise(fed_data):
    """The chunked streaming fold vmaps too: a streaming+chunked group
    stays bitwise-equal to its solo streaming runs."""
    model, data, tx, ty = fed_data
    base = _base(aggregator="diversefl", streaming=True, client_chunk=4,
                 attack=AttackConfig(kind="gaussian", sigma=1e4))
    spec = SweepSpec(base=base, seeds=(0, 1))
    fed = Federation.create(model, data, tx, ty, base, FED_KEY)
    results = run_federated_sweep(model, fed, spec, inv_sqrt_lr(0.05))
    for cell, hist in zip(spec.cells(), results):
        _assert_cell_bitwise(hist, _solo(model, data, tx, ty, cell.cfg),
                             f"streaming s{cell.cfg.seed}")


# ----------------------------------------------------------------------
# structural grouping
# ----------------------------------------------------------------------

def test_structural_key_batches_data_splits_structure():
    base = _base(aggregator="diversefl",
                 attack=AttackConfig(kind="gaussian", sigma=1e4))
    k = structural_key(base)
    # data: seed, sigma/scale, f (mask-only rules)
    assert structural_key(dataclasses.replace(base, seed=7)) == k
    assert structural_key(dataclasses.replace(base, f=0)) == k
    assert structural_key(dataclasses.replace(
        base, attack=AttackConfig(kind="gaussian", sigma=2e4))) == k
    # structure: aggregator, attack kind/classes, participation, cadence
    assert structural_key(dataclasses.replace(base, aggregator="mean")) != k
    assert structural_key(dataclasses.replace(
        base, attack=AttackConfig(kind="sign_flip"))) != k
    assert structural_key(dataclasses.replace(base, participation=0.5)) != k
    assert structural_key(dataclasses.replace(base, rounds=8)) != k
    assert structural_key(dataclasses.replace(base, client_chunk=4)) != k
    bd = dataclasses.replace(base,
                             attack=AttackConfig(kind="backdoor",
                                                 source_class=1,
                                                 target_class=2))
    assert structural_key(dataclasses.replace(
        bd, attack=dataclasses.replace(bd.attack, target_class=3))) \
        != structural_key(bd)


def test_f_is_structural_for_static_shape_rules():
    """trimmed_mean consumes f as a slice width — different f, different
    trace, different group."""
    base = _base(aggregator="trimmed_mean")
    assert structural_key(dataclasses.replace(base, f=2)) \
        != structural_key(dataclasses.replace(base, f=4))
    spec = SweepSpec(base=base, seeds=(0,), fs=(2, 4))
    assert len(group_cells(spec.cells())) == 2


# ----------------------------------------------------------------------
# satellites: magnitude changes are cache hits; config validation
# ----------------------------------------------------------------------

def test_sigma_change_does_not_recompile(fed_data):
    """Once attack magnitudes are traced operands, re-running a prebuilt
    engine with a different sigma must be a jit cache hit — and must
    still apply the new sigma (different history)."""
    model, data, tx, ty = fed_data
    cfg1 = _base(aggregator="mean",
                 attack=AttackConfig(kind="gaussian", sigma=1e4))
    fed = Federation.create(model, data, tx, ty, cfg1, FED_KEY)
    engine = RoundEngine(model, fed, cfg1)
    h1 = run_federated_training(model, fed, cfg1, inv_sqrt_lr(0.05),
                                engine=engine)
    with trace_counter() as tc:
        cfg2 = dataclasses.replace(
            cfg1, attack=AttackConfig(kind="gaussian", sigma=2e4))
        h2 = run_federated_training(model, fed, cfg2, inv_sqrt_lr(0.05),
                                    engine=engine)
    assert tc.total() == 0, "sigma change retriggered a trace"
    assert not np.array_equal(_flat(h1["params"]), _flat(h2["params"])), \
        "sigma operand is dead — new magnitude did not change the run"


@pytest.mark.parametrize("bad", [0, -1, 1.5, True])
def test_client_chunk_validation(bad):
    with pytest.raises(ValueError, match="client_chunk"):
        FLConfig(client_chunk=bad)


@pytest.mark.parametrize("bad", [0, -3, 2.0, False])
def test_stream_shards_validation(bad):
    with pytest.raises(ValueError, match="stream_shards"):
        FLConfig(stream_shards=bad)


def test_shape_knob_validation_accepts_valid():
    assert FLConfig(client_chunk=8, stream_shards=2).client_chunk == 8
    assert FLConfig().stream_shards is None
