"""Properties of the baseline robust aggregators (Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import aggregators as agg


def _updates(n=9, d=40, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def test_flatten_updates_roundtrip():
    tree = {"a": jnp.arange(12.0).reshape(3, 2, 2),
            "b": jnp.arange(3.0).reshape(3, 1)}
    flat, unravel = agg.flatten_updates(tree)
    assert flat.shape == (3, 5)
    rec = unravel(flat[1])
    np.testing.assert_allclose(rec["a"], tree["a"][1])
    np.testing.assert_allclose(rec["b"], tree["b"][1])


def test_oracle_mean_over_benign():
    u = jnp.asarray(_updates())
    mask = jnp.asarray([True] * 6 + [False] * 3)
    got = agg.oracle_sgd(u, mask)
    np.testing.assert_allclose(got, np.asarray(u)[:6].mean(0), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 15), st.integers(1, 4))
def test_median_bounded_by_benign_range(n, f):
    """With f < n/2 corrupted rows, the coordinate median stays within the
    benign min/max (the classic robustness property)."""
    if 2 * f >= n:
        return
    rng = np.random.default_rng(n * 10 + f)
    u = rng.normal(size=(n, 16)).astype(np.float32)
    u[:f] = 1e9
    med = np.asarray(agg.median(jnp.asarray(u)))
    lo, hi = u[f:].min(0), u[f:].max(0)
    assert (med >= lo - 1e-5).all() and (med <= hi + 1e-5).all()


def test_trimmed_mean_drops_extremes():
    u = _updates(7, 10, 3)
    u[0] = 1e7
    u[1] = -1e7
    for mode in ("beta", "near_median"):
        out = np.asarray(agg.trimmed_mean(jnp.asarray(u), 2, mode=mode))
        assert np.abs(out).max() < 1e3


def test_krum_selects_benign_under_attack():
    rng = np.random.default_rng(0)
    u = rng.normal(size=(9, 30)).astype(np.float32) * 0.1
    u[7:] += 100.0          # 2 byzantine outliers
    pick = np.asarray(agg.krum(jnp.asarray(u), f=2))
    # selected update must be one of the benign rows
    dists = np.abs(u - pick[None]).sum(1)
    assert dists.argmin() < 7


def test_bulyan_robust_to_outliers():
    rng = np.random.default_rng(1)
    u = rng.normal(size=(11, 20)).astype(np.float32) * 0.1
    u[0] = 1e6
    u[4] = -1e6
    out = np.asarray(agg.bulyan(jnp.asarray(u), f=2))
    assert np.abs(out).max() < 10.0


def test_fltrust_zeroes_negative_cosine():
    root = jnp.ones((16,))
    u = jnp.stack([jnp.ones((16,)), -jnp.ones((16,)), 2 * jnp.ones((16,))])
    out = np.asarray(agg.fltrust(u, root))
    # the -1 row has ReLU'd trust 0; others are rescaled to ||root||
    np.testing.assert_allclose(out, np.ones(16), rtol=1e-5)


def test_fltrust_rescales_large_updates():
    root = jnp.ones((4,)) * 2.0
    u = jnp.stack([jnp.ones((4,)) * 1e6])
    out = np.asarray(agg.fltrust(u, root))
    np.testing.assert_allclose(np.linalg.norm(out), np.linalg.norm(root),
                               rtol=1e-4)


def test_resampling_uses_each_client_at_most_s_times():
    u = jnp.asarray(_updates(8, 5, 2))
    out = agg.resampling(u, jax.random.PRNGKey(0), s_r=2)
    assert out.shape == (5,)
    assert np.isfinite(np.asarray(out)).all()


def test_kernel_and_reference_aggregators_agree():
    """The Pallas robust_agg kernel must agree with aggregators.median."""
    from repro.kernels import ops
    u = jnp.asarray(_updates(23, 200, 5))
    med_k, _ = ops.robust_aggregate(u, f=5)
    np.testing.assert_allclose(med_k, agg.median(u), rtol=1e-5, atol=1e-6)
