"""Enclave simulation: attestation, sealed storage, EPC budget, Fig.9 model."""
import jax.numpy as jnp
import numpy as np

from repro.core.tee import EPC_BYTES, Enclave


def test_attestation_roundtrip():
    e = Enclave("diversefl-enclave-v1")
    q = e.attest(nonce=42)
    assert Enclave.verify_quote(q, "diversefl-enclave-v1", 42)
    assert not Enclave.verify_quote(q, "evil-enclave", 42)
    assert not Enclave.verify_quote(q, "diversefl-enclave-v1", 43)


def test_seal_unseal_roundtrip():
    e = Enclave()
    x = np.random.default_rng(0).normal(size=(5, 7)).astype(np.float32)
    y = np.arange(5, dtype=np.int32)
    e.seal_samples(3, x, y)
    # sealed blob is not plaintext
    assert e._store[3] != x.tobytes() + y.tobytes()
    xr, yr = e.unseal_samples(3)
    np.testing.assert_allclose(xr, x)
    np.testing.assert_array_equal(yr, y)


def test_epc_budget_paging_events():
    e = Enclave(epc_bytes=1024)
    big = np.zeros((64, 16), np.float32)   # 4KB > 1KB budget
    e.seal_samples(0, big, np.zeros(64, np.int32))
    assert e.paging_events >= 1


def test_paging_events_proportional_to_spilled_pages():
    """Fig. 9 cost model: spillover is paged per 4 KB beyond the EPC
    budget, not one event per seal call."""
    e = Enclave(epc_bytes=0)
    # blob = 256*4*4 (x) + 256*4 (y) = 5120 B over budget -> 2 pages
    e.seal_samples(0, np.zeros((256, 4), np.float32),
                   np.zeros(256, np.int32))
    assert e.paging_events == 2
    # 10x the bytes -> 51200 B newly over budget -> ceil(51200/4096) = 13
    e.seal_samples(1, np.zeros((2560, 4), np.float32),
                   np.zeros(2560, np.int32))
    assert e.paging_events == 2 + 13


def test_within_budget_seals_cost_no_paging():
    e = Enclave()   # default 128 MB budget
    e.seal_samples(0, np.zeros((64, 16), np.float32), np.zeros(64, np.int32))
    assert e.paging_events == 0


def test_drop_client():
    e = Enclave()
    e.seal_samples(1, np.zeros((2, 2), np.float32), np.zeros(2, np.int32))
    assert e.client_ids() == [1]
    e.drop_client(1)
    assert e.client_ids() == []


def test_max_clients_model_matches_paper_shape():
    # small model, fits EPC: many clients; big model: paging penalty
    small = Enclave.max_clients(guide_flops=1e6, client_step_seconds=1.0)
    big = Enclave.max_clients(guide_flops=1e6, client_step_seconds=1.0,
                              model_bytes=EPC_BYTES * 2)
    assert small > big >= 1
    # scaling the sample (flops) 3x reduces supported clients ~3x (Fig. 9b)
    third = Enclave.max_clients(guide_flops=3e6, client_step_seconds=1.0)
    assert abs(small / third - 3) < 0.2
