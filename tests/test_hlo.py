"""Unit tests for launch/hlo.py — the compiled-HLO collective census.

The dryrun harness and benchmarks/comm_bench.py both trust this parser
to turn compiled module text into collective byte counts; these tests
pin it against a hand-written HLO fixture (every dtype, tuple-result
async starts, metadata lines that must NOT match) so a regex regression
shows up here instead of as silently-wrong roofline numbers.
"""
import math

import pytest

from repro.launch import hlo


# ----------------------------------------------------------------------
# _shape_bytes: the full dtype table
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype,nbytes", sorted(hlo.DTYPE_BYTES.items()))
def test_shape_bytes_dtype_table(dtype, nbytes):
    assert hlo._shape_bytes(dtype, "8,4") == 32 * nbytes


def test_shape_bytes_scalar():
    # "f32[]" — empty dims is one element, not zero
    assert hlo._shape_bytes("f32", "") == 4
    assert hlo._shape_bytes("pred", "") == 1


def test_shape_bytes_1d():
    assert hlo._shape_bytes("bf16", "1000") == 2000


# ----------------------------------------------------------------------
# collective_stats on a hand-written HLO fixture
# ----------------------------------------------------------------------

FIXTURE = """\
HloModule jit_step, entry_computation_layout={...}

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  %ar = f32[128,256] all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag.s = (bf16[64], bf16[256]) all-gather-start(%x), dimensions={0}
  %ag.d = bf16[256] all-gather-done(%ag.s)
  %rs = f32[32] reduce-scatter(%y), dimensions={0}, to_apply=%add
  %cp = u8[16] collective-permute(%z), source_target_pairs={{0,1}}
  ROOT %out = f32[128,256] add(%ar, %ar)
}
// a bare mention of all-reduce or all-gather in a comment is ignored
"""


def test_collective_stats_counts():
    stats = hlo.collective_stats(FIXTURE)
    assert stats["all-reduce"]["count"] == 1
    assert stats["all-gather"]["count"] == 1        # the -start form
    assert stats["reduce-scatter"]["count"] == 1
    assert stats["collective-permute"]["count"] == 1
    assert stats["all-to-all"]["count"] == 0


def test_collective_stats_result_bytes():
    stats = hlo.collective_stats(FIXTURE)
    assert stats["all-reduce"]["result_bytes"] == 128 * 256 * 4
    # tuple-result async start: both tuple elements sum
    assert stats["all-gather"]["result_bytes"] == (64 + 256) * 2
    assert stats["reduce-scatter"]["result_bytes"] == 32 * 4
    assert stats["collective-permute"]["result_bytes"] == 16


def test_collective_stats_moved_bytes_factors():
    stats = hlo.collective_stats(FIXTURE)
    # all-reduce counts twice (reduce + broadcast phases)
    assert stats["all-reduce"]["moved_bytes"] == \
        pytest.approx(2.0 * 128 * 256 * 4)
    assert stats["all-gather"]["moved_bytes"] == pytest.approx((64 + 256) * 2)


def test_collective_stats_done_lines_do_not_double_count():
    # the all-gather-done line must not add a second all-gather
    stats = hlo.collective_stats(FIXTURE)
    total = sum(v["count"] for v in stats.values())
    assert total == 4


def test_total_collective_bytes_sums_moved():
    stats = hlo.collective_stats(FIXTURE)
    assert hlo.total_collective_bytes(FIXTURE) == pytest.approx(
        sum(v["moved_bytes"] for v in stats.values()))
    expected = (2.0 * 128 * 256 * 4) + (64 + 256) * 2 + 32 * 4 + 16
    assert hlo.total_collective_bytes(FIXTURE) == pytest.approx(expected)


def test_empty_module_is_all_zero():
    stats = hlo.collective_stats("HloModule empty\n")
    assert all(v["count"] == 0 and v["moved_bytes"] == 0.0
               for v in stats.values())
    assert hlo.total_collective_bytes("") == 0.0


def test_op_census_counts_collectives_and_fusions():
    text = FIXTURE + "  %f = f32[8] fusion(%p0), kind=kLoop\n"
    census = hlo.op_census(text)
    assert census["all-reduce"] == 1
    assert census["all-gather"] == 1
    assert census["fusion"] == 1


def test_roofline_dominant_term():
    r = hlo.roofline_terms({"flops": 1e15, "bytes accessed": 1.0}, 1.0)
    assert r["dominant"] == "compute"
    r = hlo.roofline_terms({"flops": 1.0, "bytes accessed": 1e14}, 1.0)
    assert r["dominant"] == "memory"
    r = hlo.roofline_terms({}, 1e13)
    assert r["dominant"] == "collective"
    assert math.isfinite(r["t_collective"])
