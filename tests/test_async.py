"""Async federated rounds (ISSUE 10): cohorts, faults, bounded staleness.

Pinned here, per DESIGN.md §13:

* the fault registry's named validation errors (``FaultConfig``,
  ``FLConfig`` async knobs, ``DegenerateCohortError``);
* the cohort chain: shape, per-round size, determinism, validation;
* the compatibility tiers — trivial async (full cohort, no faults,
  zero buffer) bitwise-equal to the baseline engine path, and the
  engine vs the seed per-round loop agreeing bitwise under real async;
* the non-finite guard: NaN/Inf rows weighted out of the streaming
  fold (values sanitized, not just weights), popcounted into the
  telemetry block, inert on finite data;
* staleness bookkeeping: stragglers buffered then folded (buffer > 0)
  or expired (buffer 0), committed to the audit chain;
* attack x fault composition: a Byzantine straggler is judged by
  Eq. 6 where it LANDS, with ``mask_rates(..., valid=)`` restricting
  the TPR/FPR accounting to rows that actually participated;
* ``round_telemetry_bytes`` pricing the async telemetry fields;
* ``SweepSpec.faults`` / ``.stalenesses`` as structural axes, each
  cell bitwise-equal to its solo run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.data import FederatedData, make_classification
from repro.data.partition import partition_sorted_shards
from repro.fl import (DegenerateCohortError, Federation, FLConfig,
                      FaultConfig, SweepSpec, run_federated_sweep,
                      run_federated_training, structural_key, telemetry)
from repro.fl.faults import (cohort_size, corrupt_updates, draw_faults,
                             make_cohort_chain, validate_cohort_chain)
from repro.fl.metrics import mask_rates, round_telemetry_bytes
from repro.fl.server import AggregationContext
from repro.fl.small_models import softmax_regression
from repro.fl.streaming import get_streaming, stream_aggregate
from repro.fl.sweep import group_cells
from repro.optim import inv_sqrt_lr

N, F, DIM, NC = 23, 5, 8, 4
FED_KEY = jax.random.PRNGKey(2)


@pytest.fixture(scope="module")
def fed_data():
    x, y = make_classification(jax.random.PRNGKey(0), N * 16, NC, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N), NC)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, NC, DIM)
    return softmax_regression(input_dim=DIM, n_classes=NC), data, tx, ty


def _cfg(**kw):
    kw.setdefault("n_clients", N)
    kw.setdefault("f", F)
    kw.setdefault("rounds", 4)
    kw.setdefault("eval_every", 2)
    kw.setdefault("batch_size", 4)
    kw.setdefault("l2", 0.0)
    kw.setdefault("aggregator", "diversefl")
    kw.setdefault("streaming", True)
    kw.setdefault("attack", AttackConfig(kind="sign_flip"))
    return FLConfig(**kw)


def _train(fed_data, cfg, **kw):
    model, data, tx, ty = fed_data
    fed = Federation.create(model, data, tx, ty, cfg, FED_KEY)
    return run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05),
                                  **kw), fed


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def _assert_hist_bitwise(a, b, label):
    assert np.array_equal(_flat(a["params"]), _flat(b["params"])), \
        f"{label}: final params differ"
    assert set(a) == set(b), f"{label}: history keys differ"
    for k in a:
        if k != "params":
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), \
                f"{label}: history[{k!r}] differs"


def _audit_kinds(fed):
    kinds = {}
    for e in fed.server.audit.entries:
        kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
    return kinds


# ----------------------------------------------------------------------
# named-error validation
# ----------------------------------------------------------------------

def test_fault_config_named_errors():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultConfig(kind="meteor")
    with pytest.raises(ValueError, match="rate must be in"):
        FaultConfig(kind="dropout", rate=1.5)
    with pytest.raises(ValueError, match="delay must be a positive int"):
        FaultConfig(kind="straggler", delay=0)
    with pytest.raises(ValueError, match="delay must be a positive int"):
        FaultConfig(kind="straggler", delay=True)
    with pytest.raises(ValueError, match="unknown corruption mode"):
        FaultConfig(kind="intermittent", rate=0.1, mode="gamma_ray")


def test_flconfig_async_named_errors():
    with pytest.raises(ValueError, match="cohort_participation"):
        _cfg(cohort_participation=0.0)
    with pytest.raises(ValueError, match="cohort_participation"):
        _cfg(cohort_participation=1.5)
    with pytest.raises(ValueError, match="staleness_buffer"):
        _cfg(staleness_buffer=-1)
    with pytest.raises(ValueError, match="staleness_discount"):
        _cfg(staleness_discount=0.0)
    # async replaces the static participation subsample
    with pytest.raises(ValueError, match="cohort_participation"):
        _cfg(cohort_participation=0.5, participation=0.5)
    # async needs the streaming fold...
    with pytest.raises(ValueError, match="streaming"):
        _cfg(cohort_participation=0.5, streaming=False)
    # ...a rule that CAN stream...
    with pytest.raises(ValueError, match="streaming"):
        _cfg(cohort_participation=0.5, aggregator="median")
    # ...and a lossless wire format
    with pytest.raises(ValueError, match="lossy"):
        _cfg(cohort_participation=0.5, compression="int8")


def test_cohort_chain_shape_size_determinism():
    key = jax.random.PRNGKey(7)
    chain = make_cohort_chain(N, 6, 0.5, key)
    assert chain.shape == (6, N) and chain.dtype == bool
    c = cohort_size(N, 0.5)
    assert np.all(np.asarray(chain.sum(axis=1)) == c)
    assert np.array_equal(np.asarray(chain),
                          np.asarray(make_cohort_chain(N, 6, 0.5, key)))
    # rows actually resample (astronomically unlikely to all coincide)
    assert not all(np.array_equal(np.asarray(chain[0]), np.asarray(chain[r]))
                   for r in range(1, 6))
    assert cohort_size(N, 1e-9) == 1 and cohort_size(N, 1.0) == N


def test_explicit_chain_validation():
    validate_cohort_chain(jnp.ones((3, N), bool), N, 3)
    with pytest.raises(DegenerateCohortError, match="shape"):
        validate_cohort_chain(jnp.ones((3, N + 1), bool), N, 3)
    bad = jnp.ones((3, N), bool).at[1].set(False)
    with pytest.raises(DegenerateCohortError, match="round 1"):
        validate_cohort_chain(bad, N, 3)


def test_draw_and_corrupt_primitives():
    key = jax.random.PRNGKey(0)
    assert not np.any(np.asarray(draw_faults(key, N, FaultConfig())))
    rows = draw_faults(key, 1000, FaultConfig(kind="dropout", rate=0.3))
    frac = float(np.mean(np.asarray(rows)))
    assert 0.2 < frac < 0.4
    U = jnp.ones((4, 6), jnp.float32)
    hit = jnp.asarray([True, False, True, False])
    out = np.asarray(corrupt_updates(
        U, hit, FaultConfig(kind="intermittent", rate=0.5, mode="nan")))
    assert np.all(np.isnan(out[[0, 2]])) and np.array_equal(
        out[[1, 3]], np.ones((2, 6), np.float32))
    out = np.asarray(corrupt_updates(
        U, hit, FaultConfig(kind="intermittent", rate=0.5, mode="bitflip",
                            bitflip_scale=8.0)))
    assert np.all(out[[0, 2]] == 8.0) and np.all(out[[1, 3]] == 1.0)
    # non-intermittent kinds pass through bitwise
    same = corrupt_updates(U, hit, FaultConfig(kind="straggler", rate=0.5))
    assert same is U


# ----------------------------------------------------------------------
# compatibility tiers
# ----------------------------------------------------------------------

def test_trivial_async_bitwise_vs_baseline(fed_data):
    base, _ = _train(fed_data, _cfg())
    triv, _ = _train(fed_data, _cfg(cohort_participation=1.0))
    _assert_hist_bitwise(base, triv, "trivial-async")


def test_async_engine_matches_seed_loop(fed_data):
    cfg = _cfg(cohort_participation=0.6,
               fault=FaultConfig(kind="dropout", rate=0.3))
    eng, _ = _train(fed_data, cfg)
    seed, _ = _train(fed_data, cfg, use_engine=False)
    _assert_hist_bitwise(eng, seed, "engine-vs-seed-loop")


# ----------------------------------------------------------------------
# faults end to end
# ----------------------------------------------------------------------

def test_dropout_cohort_run_and_audit(fed_data):
    cfg = _cfg(rounds=6, cohort_participation=0.6, telemetry=True,
               fault=FaultConfig(kind="dropout", rate=0.3))
    with telemetry.recording() as rec:
        hist, fed = _train(fed_data, cfg)
    assert np.isfinite(_flat(hist["params"])).all()
    rounds = [r for r in rec.records if r.get("kind") == "round"]
    assert len(rounds) == 6
    # live cohort = resampled cohort minus dropouts, committed per round
    assert all(0 <= r["cohort"] <= cohort_size(N, 0.6) for r in rounds)
    assert any(r["cohort"] < cohort_size(N, 0.6) for r in rounds)
    kinds = _audit_kinds(fed)
    assert kinds.get("cohort_resample") == 6
    assert telemetry.verify_entries(fed.server.audit.entries)


def test_intermittent_nan_guard_end_to_end(fed_data):
    cfg = _cfg(rounds=6, telemetry=True,
               fault=FaultConfig(kind="intermittent", rate=0.4, mode="nan"))
    with telemetry.recording() as rec:
        hist, _fed = _train(fed_data, cfg)
    # 40% of clients burst NaN every round; the guard must keep the
    # model finite and the telemetry must count the screened rows
    assert np.isfinite(_flat(hist["params"])).all()
    assert np.isfinite(np.asarray(hist["acc"])).all()
    rounds = [r for r in rec.records if r.get("kind") == "round"]
    assert sum(r["nonfinite"] for r in rounds) > 0


def test_straggler_buffered_then_folded(fed_data):
    cfg = _cfg(rounds=6, staleness_buffer=N, telemetry=True,
               fault=FaultConfig(kind="straggler", rate=0.4, delay=1))
    with telemetry.recording() as rec:
        hist, fed = _train(fed_data, cfg)
    assert np.isfinite(_flat(hist["params"])).all()
    rounds = [r for r in rec.records if r.get("kind") == "round"]
    buf = sum(r["stale_buffered"] for r in rounds)
    fold = sum(r["stale_folded"] for r in rounds)
    exp = sum(r["stale_expired"] for r in rounds)
    assert buf > 0 and fold > 0
    assert exp == 0                         # N slots never overflow
    assert fold <= buf                      # land only what was buffered
    # delay=1: everything buffered in rounds 1..R-1 lands next round
    assert fold == sum(r["stale_buffered"] for r in rounds[:-1])
    kinds = _audit_kinds(fed)
    assert kinds.get("stale_buffered", 0) > 0
    assert kinds.get("stale_folded", 0) > 0
    assert "stale_expired" not in kinds     # zero counts stay off the chain
    assert telemetry.verify_entries(fed.server.audit.entries)


def test_straggler_without_buffer_expires(fed_data):
    cfg = _cfg(rounds=6, telemetry=True,
               fault=FaultConfig(kind="straggler", rate=0.4, delay=1))
    with telemetry.recording() as rec:
        hist, fed = _train(fed_data, cfg)
    assert np.isfinite(_flat(hist["params"])).all()
    rounds = [r for r in rec.records if r.get("kind") == "round"]
    assert sum(r["stale_expired"] for r in rounds) > 0
    assert sum(r["stale_buffered"] for r in rounds) == 0
    assert sum(r["stale_folded"] for r in rounds) == 0
    kinds = _audit_kinds(fed)
    assert kinds.get("stale_expired", 0) > 0 and "stale_folded" not in kinds


def test_staleness_cap_expires_over_delay(fed_data):
    # cap < delay: the buffer exists but refuses everything (static)
    cfg = _cfg(rounds=4, staleness_buffer=4, staleness_cap=1,
               telemetry=True,
               fault=FaultConfig(kind="straggler", rate=0.4, delay=2))
    with telemetry.recording() as rec:
        hist, _fed = _train(fed_data, cfg)
    assert np.isfinite(_flat(hist["params"])).all()
    rounds = [r for r in rec.records if r.get("kind") == "round"]
    assert sum(r["stale_expired"] for r in rounds) > 0
    assert sum(r["stale_folded"] for r in rounds) == 0


# ----------------------------------------------------------------------
# attack x fault composition
# ----------------------------------------------------------------------

def test_mask_rates_valid_channel_exact():
    mask = jnp.asarray([True, False, False, True, False, True])
    byz = jnp.asarray([False, True, True, False, True, False])
    valid = jnp.asarray([True, True, False, True, False, False])
    # all-rows accounting unchanged
    tpr, fpr = mask_rates(mask, byz)
    assert float(tpr) == 1.0 and float(fpr) == 0.0
    # valid restricts both numerators and denominators to live rows:
    # byz rows {1} live (flagged), benign rows {0, 3} live (kept)
    tpr, fpr = mask_rates(mask, byz, valid)
    assert float(tpr) == 1.0 and float(fpr) == 0.0
    # a kept Byzantine row only counts against TPR while it is live
    tpr_live, _ = mask_rates(mask.at[1].set(True), byz, valid)
    tpr_dead, _ = mask_rates(mask.at[1].set(True), byz,
                             valid.at[1].set(False))
    assert float(tpr_live) == 0.0 and float(tpr_dead) == 1.0
    # degenerate live cohorts keep the legacy conventions
    none_live = jnp.zeros((6,), bool)
    tpr, fpr = mask_rates(mask, byz, none_live)
    assert float(tpr) == 1.0 and float(fpr) == 0.0


def test_byzantine_straggler_tagged_at_landing(fed_data):
    # sign-flipped Byzantine clients straggle: their updates land a
    # round late and Eq. 6 (guides recomputed at the landing round)
    # must still tag them — detection follows the update, not the round
    cfg = _cfg(rounds=6, staleness_buffer=N,
               fault=FaultConfig(kind="straggler", rate=0.5, delay=1))
    hist, _fed = _train(fed_data, cfg)
    assert np.isfinite(_flat(hist["params"])).all()
    assert float(np.asarray(hist["mask_tpr"])[-1]) >= 0.99
    assert float(np.asarray(hist["mask_fpr"])[-1]) <= 0.5


# ----------------------------------------------------------------------
# the non-finite guard, unit level
# ----------------------------------------------------------------------

def test_nonfinite_guard_unit():
    d = 17
    rng = np.random.default_rng(3)
    U = rng.normal(size=(8, d)).astype(np.float32)
    U[2] = np.nan
    U[5, 0] = np.inf
    rule = get_streaming("mean").bind(AggregationContext())

    def block_fn(blk, valid):
        (u_b,) = blk
        return u_b, {}

    delta, _agg, logs = stream_aggregate(rule, block_fn, (jnp.asarray(U),),
                                         4, d=d)
    assert np.array_equal(np.asarray(logs["nonfinite"]),
                          [False, False, True, False, False, True,
                           False, False])
    fin = np.delete(U, [2, 5], axis=0)
    assert np.isfinite(np.asarray(delta)).all()
    # screened rows contribute exactly 0 to numerator AND denominator
    np.testing.assert_allclose(np.asarray(delta),
                               fin.sum(axis=0) / len(fin), rtol=1e-6)
    # inert on finite data: same fold, nonfinite bits all clear
    d2, _a2, logs2 = stream_aggregate(rule, block_fn,
                                      (jnp.ones((8, d), jnp.float32),),
                                      4, d=d)
    assert not np.any(np.asarray(logs2["nonfinite"]))
    assert np.array_equal(np.asarray(d2), np.ones(d, np.float32))


def test_round_telemetry_bytes_async_fields(fed_data):
    sync_cfg = _cfg()
    async_cfg = _cfg(cohort_participation=0.5)
    # streaming raw-f32 carries the nonfinite popcount either way; async
    # adds cohort + the three staleness decision counts (4 x int32)
    assert round_telemetry_bytes(async_cfg) \
        == round_telemetry_bytes(sync_cfg) + 16
    # lossy codec drops the guard field on an otherwise-equal config
    assert round_telemetry_bytes(_cfg(compression="int8")) \
        == round_telemetry_bytes(sync_cfg) - 4


# ----------------------------------------------------------------------
# sweep axes
# ----------------------------------------------------------------------

def test_sweep_fault_staleness_axes_structural(fed_data):
    base = _cfg()
    spec = SweepSpec(
        base=base, seeds=(0,),
        faults=(FaultConfig(),
                FaultConfig(kind="straggler", rate=0.4, delay=1)),
        stalenesses=(0, 4))
    cells = spec.cells()
    assert len(cells) == 4
    assert len(group_cells(cells)) == 4      # every point its own trace
    keys = {structural_key(c.cfg) for c in cells}
    assert len(keys) == 4
    # seeds batch within a (fault, staleness) point
    spec2 = dataclasses.replace(spec, seeds=(0, 1))
    assert len(group_cells(spec2.cells())) == 4


def test_sweep_async_cells_bitwise_vs_solo(fed_data):
    model, data, tx, ty = fed_data
    base = _cfg(cohort_participation=0.6)
    spec = SweepSpec(
        base=base, seeds=(0, 1),
        faults=(FaultConfig(kind="dropout", rate=0.3),))
    fed = Federation.create(model, data, tx, ty, base, FED_KEY)
    results = run_federated_sweep(model, fed, spec, inv_sqrt_lr(0.05))
    assert len(results) == 2
    for cell, got in zip(spec.cells(), results):
        solo, _ = _train(fed_data, cell.cfg)
        _assert_hist_bitwise(solo, got, f"cell seed={cell.cfg.seed}")
