"""RoundEngine / simulator equivalence (ISSUE 2 satellite).

The scan-compiled engine must be a pure compilation strategy, not a new
algorithm: with ``eval_every=1`` and ``client_chunk=N`` it reproduces
the seed per-round jitted loop bit-for-bit on fixed seeds, and chunked
execution (``client_chunk < N``) matches unchunked to fp tolerance
across aggregators.  The segment-stack batch mode and the mesh-sharded
path must be bit-identical to the inline path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.attacks import AttackConfig
from repro.data import (FederatedData, make_mnist_like,
                        partition_sorted_shards)
from repro.fl import (FLConfig, Federation, RoundEngine, chunked_vmap,
                      run_federated_training, softmax_regression)
from repro.optim import inv_sqrt_lr

N_CLIENTS, F, ROUNDS = 23, 5, 6


@pytest.fixture(scope="module")
def small_fed():
    x, y = make_mnist_like(jax.random.PRNGKey(0), 460)
    tx, ty = make_mnist_like(jax.random.PRNGKey(9), 200)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), 10)
    return data, tx, ty


def _cfg(**kw):
    kw.setdefault("n_clients", N_CLIENTS)
    kw.setdefault("f", F)
    kw.setdefault("rounds", ROUNDS)
    kw.setdefault("batch_size", 10)
    kw.setdefault("eval_every", 3)
    kw.setdefault("attack", AttackConfig(kind="sign_flip"))
    return FLConfig(**kw)


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def _train(data, tx, ty, cfg, **kw):
    model = softmax_regression()
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    return run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05), **kw)


# ----------------------------------------------------------------------
# scan engine vs seed per-round loop: bit-for-bit
# ----------------------------------------------------------------------

@pytest.mark.parametrize("eval_every", [1, 3])
def test_scan_engine_reproduces_seed_loop_bitwise(small_fed, eval_every):
    data, tx, ty = small_fed
    cfg = _cfg(eval_every=eval_every)
    h_eng = _train(data, tx, ty, cfg)
    h_seed = _train(data, tx, ty, cfg, use_engine=False)
    assert np.array_equal(_flat(h_eng["params"]), _flat(h_seed["params"]))
    assert h_eng["round"] == h_seed["round"]
    assert h_eng["acc"] == h_seed["acc"]
    assert h_eng["mask_tpr"] == h_seed["mask_tpr"]
    assert h_eng["mask_fpr"] == h_seed["mask_fpr"]


def test_chunk_equal_to_n_is_bitwise(small_fed):
    """client_chunk=N must take the exact vmap path (same traced graph)."""
    data, tx, ty = small_fed
    h_full = _train(data, tx, ty, _cfg())
    h_cn = _train(data, tx, ty, _cfg(client_chunk=N_CLIENTS))
    assert np.array_equal(_flat(h_full["params"]), _flat(h_cn["params"]))


# ----------------------------------------------------------------------
# chunked vs unchunked: fp tolerance, >= 3 aggregators
# ----------------------------------------------------------------------

@pytest.mark.parametrize("aggregator",
                         ["diversefl", "mean", "trimmed_mean", "krum"])
@pytest.mark.parametrize("chunk", [4, 10])
def test_chunked_matches_unchunked(small_fed, aggregator, chunk):
    data, tx, ty = small_fed
    h_full = _train(data, tx, ty, _cfg(aggregator=aggregator, rounds=4))
    h_chunk = _train(data, tx, ty,
                     _cfg(aggregator=aggregator, rounds=4,
                          client_chunk=chunk))
    np.testing.assert_allclose(_flat(h_chunk["params"]),
                               _flat(h_full["params"]),
                               rtol=1e-5, atol=1e-6)


def test_chunked_vmap_matches_vmap_with_padding():
    """Non-divisible chunking (pad + discard) equals plain vmap."""
    xs = jnp.arange(21.0).reshape(7, 3)
    fn = lambda row: jnp.sum(row ** 2) + row
    want = jax.vmap(fn)(xs)
    for chunk in (1, 2, 3, 4, 7, 100):
        got = chunked_vmap(fn, (xs,), chunk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------------------
# batch modes and mesh sharding
# ----------------------------------------------------------------------

def _engine_segment(model, fed, cfg, **kw):
    engine = RoundEngine(model, fed, cfg, **kw)
    params0 = model.init(jax.random.PRNGKey(cfg.seed + 1))
    lrs = [float(inv_sqrt_lr(0.05)(r)) for r in range(1, 4)]
    return engine.run_segment(params0, jax.random.PRNGKey(cfg.seed), lrs)


def test_segment_batch_mode_is_bitwise(small_fed):
    """Per-segment minibatch stacks (data pipeline) == in-body sampling."""
    data, tx, ty = small_fed
    cfg = _cfg()
    model = softmax_regression()
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    p_in, k_in, _ = _engine_segment(model, fed, cfg, batch_mode="inline")
    p_seg, k_seg, _ = _engine_segment(model, fed, cfg, batch_mode="segment")
    assert np.array_equal(_flat(p_in), _flat(p_seg))
    assert np.array_equal(np.asarray(k_in), np.asarray(k_seg))


def test_mesh_sharded_engine_is_bitwise(small_fed):
    """An active ("data","model") mesh (client-axis NamedShardings +
    segment batch stacks) must not change the numbers."""
    data, tx, ty = small_fed
    cfg = _cfg(client_chunk=8)
    model = softmax_regression()
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    p_ref, _, _ = _engine_segment(model, fed, cfg, batch_mode="inline")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    p_mesh, _, logs = _engine_segment(model, fed, cfg, mesh=mesh)
    assert np.array_equal(_flat(p_ref), _flat(p_mesh))
    assert "mask" in logs


# ----------------------------------------------------------------------
# satellite fixes
# ----------------------------------------------------------------------

def test_n_selected_uses_ceil():
    """Step 2: C = ceil(participation * N); round() under-selected."""
    cfg = FLConfig(n_clients=23, participation=0.1)
    assert cfg.n_selected == 3          # round(2.3) == 2 was the bug
    assert FLConfig(n_clients=23, participation=1.0).n_selected == 23
    assert FLConfig(n_clients=23, participation=0.5).n_selected == 12
    assert FLConfig(n_clients=10, participation=0.0).n_selected == 1


def test_engine_partial_participation_matches_seed(small_fed):
    """Selection RNG (ks subkey) is part of the bit-for-bit contract."""
    data, tx, ty = small_fed
    cfg = _cfg(participation=0.5, rounds=4)
    h_eng = _train(data, tx, ty, cfg)
    h_seed = _train(data, tx, ty, cfg, use_engine=False)
    assert np.array_equal(_flat(h_eng["params"]), _flat(h_seed["params"]))


def test_compute_guides_select_and_chunk(small_fed):
    """Chunked + selected guide computation equals the full vmap path."""
    data, tx, ty = small_fed
    cfg = _cfg()
    model = softmax_regression()
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    params = model.init(jax.random.PRNGKey(1))

    def grad_fn(p, batch):
        x, y = batch
        return jax.grad(lambda q: model.loss(q, x, y))(p)

    full = fed.server.compute_guides(params, grad_fn, lr=0.05, E=2)
    sel = jnp.asarray([3, 7, 11, 19, 2])
    picked = fed.server.compute_guides(params, grad_fn, lr=0.05, E=2,
                                       select=sel)
    chunked = fed.server.compute_guides(params, grad_fn, lr=0.05, E=2,
                                        select=sel, client_chunk=2)
    want = jax.tree.map(lambda u: u[np.asarray(sel)], full)
    for a, b, c in zip(jax.tree.leaves(want), jax.tree.leaves(picked),
                       jax.tree.leaves(chunked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-6, atol=1e-7)


def test_segment_logs_are_last_round(small_fed):
    """run_segment returns the final round's logs (what the eval reads)."""
    data, tx, ty = small_fed
    cfg = _cfg()
    model = softmax_regression()
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    _, _, logs = _engine_segment(model, fed, cfg)
    assert logs["mask"].shape == (cfg.n_selected,)
    assert logs["byz"].shape == (cfg.n_selected,)
