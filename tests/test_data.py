"""Data pipeline: synthetic sets, non-IID partitioners, federation stacking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (FederatedData, make_classification, make_mnist_like,
                        make_token_stream, partition_dirichlet,
                        partition_sorted_shards, partition_two_shards)


def test_classification_is_learnable_and_consistent():
    x1, y1 = make_classification(jax.random.PRNGKey(0), 500, 10, 64)
    x2, y2 = make_classification(jax.random.PRNGKey(1), 500, 10, 64)
    # same class templates across draws: class means correlate strongly
    for c in range(3):
        m1 = np.asarray(x1[y1 == c].mean(0))
        m2 = np.asarray(x2[y2 == c].mean(0))
        cos = m1 @ m2 / (np.linalg.norm(m1) * np.linalg.norm(m2))
        assert cos > 0.8


def test_sorted_shards_are_label_skewed():
    x, y = make_mnist_like(jax.random.PRNGKey(0), 2300)
    parts = partition_sorted_shards(x, y, 23)
    assert len(parts) == 23
    n_label_kinds = [len(np.unique(np.asarray(p[1]))) for p in parts]
    assert np.mean(n_label_kinds) <= 3  # extreme non-IID


def test_two_shards_partition():
    x, y = make_mnist_like(jax.random.PRNGKey(0), 2500)
    parts = partition_two_shards(x, y, 25)
    assert len(parts) == 25
    kinds = [len(np.unique(np.asarray(p[1]))) for p in parts]
    assert max(kinds) <= 4


def test_dirichlet_partition_covers_all_data():
    x, y = make_mnist_like(jax.random.PRNGKey(0), 1000)
    parts = partition_dirichlet(x, y, 10, alpha=0.3)
    assert sum(p[1].shape[0] for p in parts) == 1000


def test_federated_data_stack_and_sampling():
    x, y = make_mnist_like(jax.random.PRNGKey(0), 2300)
    fed = FederatedData.from_partitions(partition_sorted_shards(x, y, 23), 10)
    assert fed.n_clients == 23
    xb, yb = fed.minibatch(jax.random.PRNGKey(1), 16)
    assert xb.shape[:2] == (23, 16) and yb.shape == (23, 16)
    gx, gy = fed.enclave_samples(jax.random.PRNGKey(2), 0.03)
    assert gx.shape[0] == 23 and gx.shape[1] == max(1, int(fed.per_client * 0.03))


def test_token_stream_shapes_and_range():
    toks = make_token_stream(jax.random.PRNGKey(0), 4, 128, 977)
    assert toks.shape == (4, 128)
    assert int(toks.min()) >= 0 and int(toks.max()) < 977
