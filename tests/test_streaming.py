"""Streaming aggregation subsystem (ISSUE 3, DESIGN.md §6).

Three contracts:

  * **streaming == dense, bitwise** — for the masked-mean family
    (``diversefl``, ``oracle``, ``mean``) the streaming fold reproduces
    the dense (N, D) path bit for bit: delta, params trajectory and the
    per-client criterion logs, at N=256, at any chunk size (divisible or
    not), with full and partial participation.  Non-associative rules
    fall back to the dense path (bitwise trivially) with the reason
    exposed on the engine.
  * **AggState is a monoid** — ``merge`` is associative, ``init`` is its
    identity, and folding the same clients in a different chunk order
    merges to the same state (exact on integer-valued floats, fp
    tolerance on generic ones) for every registered streaming rule.
  * **chunked_vmap edge cases** — N < chunk and N not divisible by chunk
    take the padded-block path and still equal plain vmap exactly.
"""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.attacks import AttackConfig
from repro.core.diversefl import masked_mean_flat
from repro.data import FederatedData, make_classification
from repro.data.partition import partition_sorted_shards
from repro.fl import (FLConfig, Federation, RoundEngine, chunked_vmap,
                      fallback_reason, get_streaming,
                      run_federated_training, softmax_regression,
                      streaming_rules)
from repro.fl.server import KERNEL_AGG_RULES, AggregationContext, aggregate
from repro.fl.streaming import NON_STREAMING, stream_aggregate
from repro.optim import inv_sqrt_lr

N_CLIENTS, DIM, N_CLASSES = 256, 8, 4


@pytest.fixture(scope="module")
def fed_data():
    x, y = make_classification(jax.random.PRNGKey(0), N_CLIENTS * 8,
                               N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, N_CLASSES, DIM)
    return data, tx, ty


def _cfg(**kw):
    kw.setdefault("n_clients", N_CLIENTS)
    kw.setdefault("f", N_CLIENTS // 5)
    kw.setdefault("rounds", 2)
    kw.setdefault("batch_size", 2)
    kw.setdefault("eval_every", 2)
    kw.setdefault("l2", 0.0)
    kw.setdefault("attack", AttackConfig(kind="sign_flip"))
    return FLConfig(**kw)


def _train(fed_data, cfg):
    data, tx, ty = fed_data
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    return run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


# ----------------------------------------------------------------------
# streaming == dense: bitwise for the masked-mean family at N=256
# ----------------------------------------------------------------------

@pytest.mark.parametrize("aggregator", ["diversefl", "oracle", "mean"])
def test_streaming_matches_dense_bitwise(fed_data, aggregator):
    """The acceptance contract: same chunking, streaming=True folds the
    AggState to the exact bits the dense (N, D) path produces."""
    h_dense = _train(fed_data, _cfg(aggregator=aggregator, client_chunk=64))
    h_strm = _train(fed_data, _cfg(aggregator=aggregator, client_chunk=64,
                                   streaming=True))
    assert np.array_equal(_flat(h_dense["params"]), _flat(h_strm["params"]))
    assert h_dense["acc"] == h_strm["acc"]
    assert h_dense["mask_tpr"] == h_strm["mask_tpr"]
    assert h_dense["mask_fpr"] == h_strm["mask_fpr"]
    if h_dense["c1c2"]:                       # criterion logs, bit for bit
        np.testing.assert_array_equal(h_dense["c1c2"][-1], h_strm["c1c2"][-1])


def test_streaming_partial_participation_bitwise(fed_data):
    """C = ceil(0.5·N) selected ids, non-divisible chunk (pad + valid
    masking): still bitwise."""
    kw = dict(aggregator="diversefl", participation=0.5, client_chunk=48,
              attack=AttackConfig(kind="gaussian"))
    h_dense = _train(fed_data, _cfg(**kw))
    h_strm = _train(fed_data, _cfg(streaming=True, **kw))
    assert np.array_equal(_flat(h_dense["params"]), _flat(h_strm["params"]))
    assert h_dense["mask_tpr"] == h_strm["mask_tpr"]


def test_streaming_unchunked_single_block_bitwise(fed_data):
    """client_chunk=None folds one C-sized block — same bits again."""
    h_dense = _train(fed_data, _cfg(aggregator="oracle"))
    h_strm = _train(fed_data, _cfg(aggregator="oracle", streaming=True))
    assert np.array_equal(_flat(h_dense["params"]), _flat(h_strm["params"]))


def test_streaming_fltrust_weighted_mean(fed_data):
    """fltrust streams as a weighted mean (dense uses matvec cosine —
    different association, so fp tolerance, not bitwise)."""
    h_dense = _train(fed_data, _cfg(aggregator="fltrust", client_chunk=64))
    h_strm = _train(fed_data, _cfg(aggregator="fltrust", client_chunk=64,
                                   streaming=True))
    np.testing.assert_allclose(_flat(h_strm["params"]),
                               _flat(h_dense["params"]),
                               rtol=1e-5, atol=1e-6)


def test_streaming_kernel_path(fed_data):
    """use_kernel_agg accumulates per block through the streaming Pallas
    kernel (interpret mode on CPU) — block association, fp tolerance."""
    kw = dict(aggregator="diversefl", client_chunk=64)
    h_dense = _train(fed_data, _cfg(**kw))
    h_kern = _train(fed_data, _cfg(streaming=True, use_kernel_agg=True, **kw))
    np.testing.assert_allclose(_flat(h_kern["params"]),
                               _flat(h_dense["params"]),
                               rtol=1e-4, atol=1e-6)


# ----------------------------------------------------------------------
# fallback: non-associative rules stay dense, with the reason exposed
# ----------------------------------------------------------------------

def test_streaming_fallback_is_dense_and_logged(fed_data, caplog):
    data, tx, ty = fed_data
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    cfg = _cfg(aggregator="median", streaming=True, client_chunk=64)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    with caplog.at_level(logging.WARNING, logger="repro.fl.engine"):
        engine = RoundEngine(model, fed, cfg)
    assert not engine.streaming
    assert "median" in engine.streaming_fallback
    assert any("falling back" in r.message for r in caplog.records)
    # and the fallback path is numerically the dense path, trivially
    h_strm = _train(fed_data, cfg)
    h_dense = _train(fed_data, _cfg(aggregator="median", client_chunk=64))
    assert np.array_equal(_flat(h_strm["params"]), _flat(h_dense["params"]))


def test_server_streaming_aggregator_accessor():
    """SecureServer stays the aggregation choke point: the engine binds
    streaming rules through it, and dense-only names return None."""
    from repro.fl import SecureServer
    ctx = AggregationContext(byz_mask=jnp.zeros((3,), bool))
    rule = SecureServer.streaming_aggregator("oracle", ctx)
    assert rule is not None and callable(rule.update)
    assert SecureServer.streaming_aggregator("median", ctx) is None


def test_fallback_reasons_cover_non_associative_rules():
    for name in ("median", "trimmed_mean", "krum", "bulyan", "resampling"):
        assert get_streaming(name) is None
        assert fallback_reason(name) == NON_STREAMING[name]
    for name in ("mean", "oracle", "diversefl", "fltrust"):
        assert get_streaming(name) is not None
        assert fallback_reason(name) is None
    assert set(streaming_rules()) == {"mean", "oracle", "diversefl",
                                      "fltrust"}


def test_use_kernel_agg_outside_family_raises():
    for name in ("median", "krum", "bulyan", "resampling", "trimmed_mean"):
        with pytest.raises(ValueError, match="weighted-mean"):
            FLConfig(aggregator=name, use_kernel_agg=True)
    for name in KERNEL_AGG_RULES:
        FLConfig(aggregator=name, use_kernel_agg=True)   # must not raise
    # every streaming rule is in the kernel family and vice versa: the
    # two capability lists cannot disagree
    assert set(KERNEL_AGG_RULES) == set(streaming_rules())


def test_dense_fltrust_kernel_path_matches_xla():
    """The dense fltrust kernel leg (weighted-mean form through the
    streaming Pallas kernel) agrees with aggregators.fltrust."""
    rng = np.random.default_rng(5)
    U = jnp.asarray(rng.normal(size=(9, 120)).astype(np.float32))
    root = jnp.asarray(rng.normal(size=(120,)).astype(np.float32))
    base, _ = aggregate("fltrust", U, AggregationContext(root_update=root))
    kern, _ = aggregate("fltrust", U, AggregationContext(
        root_update=root, use_kernel_agg=True))
    np.testing.assert_allclose(np.asarray(kern), np.asarray(base),
                               rtol=1e-5, atol=1e-6)


def test_streaming_kernel_stats_without_kernel_agg_raises():
    """use_kernel_stats is unreachable on the streaming row-fold path —
    rejected instead of silently ignored (same class of fix as above)."""
    with pytest.raises(ValueError, match="use_kernel_stats"):
        FLConfig(aggregator="diversefl", streaming=True,
                 use_kernel_stats=True)
    # reachable combinations must not raise
    FLConfig(aggregator="diversefl", streaming=True, use_kernel_stats=True,
             use_kernel_agg=True)
    FLConfig(aggregator="diversefl", use_kernel_stats=True)


# ----------------------------------------------------------------------
# AggState monoid laws: associativity + chunk-order insensitivity
# ----------------------------------------------------------------------

def _bound_rule(name, n, d, rng):
    """A bound streaming rule plus per-client (u, ctx) rows for it."""
    U = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    byz = jnp.asarray(rng.random(n) < 0.3)
    root = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    ctx = AggregationContext(byz_mask=byz, guides=G, root_update=root)
    rule = get_streaming(name).bind(ctx)
    rows = [(U[i], {"guide": G[i], "byz": byz[i],
                    "valid": jnp.asarray(True)}) for i in range(n)]
    return rule, rows


def _fold(rule, rows, d):
    state = rule.init(d)
    for u, ci in rows:
        state, _ = rule.update(state, u, ci)
    return state


def _assert_states_close(a, b, **tol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **tol)


@given(st.sampled_from(["mean", "oracle", "diversefl", "fltrust"]),
       st.integers(min_value=0, max_value=3))
@settings(max_examples=16, deadline=None)
def test_merge_is_associative(name, seed):
    """merge(merge(a,b),c) == merge(a,merge(b,c)) — exact: merge is a
    componentwise add of two states, no data-dependent order."""
    rng = np.random.default_rng(seed)
    d = 17
    rule, rows = _bound_rule(name, 9, d, rng)
    a = _fold(rule, rows[:3], d)
    b = _fold(rule, rows[3:6], d)
    c = _fold(rule, rows[6:], d)
    left = rule.merge(rule.merge(a, b), c)
    right = rule.merge(a, rule.merge(b, c))
    # one fp add each side, same operands -> tight tolerance
    _assert_states_close(left, right, rtol=1e-6, atol=1e-7)


@given(st.sampled_from(["mean", "oracle", "diversefl"]))
@settings(max_examples=8, deadline=None)
def test_merge_identity_and_exact_associativity(name):
    """With integer-valued updates and 0/1 weights the fp adds are exact:
    the monoid laws hold bitwise, and init is the identity.  (fltrust's
    trust-score weights are irrational — it is covered by the
    fp-tolerance associativity tests above.)"""
    rng = np.random.default_rng(0)
    d = 11
    rule, _ = _bound_rule(name, 3, d, rng)
    U = jnp.asarray(rng.integers(-8, 8, size=(6, d)).astype(np.float32))
    G = jnp.asarray(np.sign(np.asarray(U)) * 1.0)   # keeps diversefl masks on
    rows = [(U[i], {"guide": G[i], "byz": jnp.asarray(False),
                    "valid": jnp.asarray(True)}) for i in range(6)]
    a = _fold(rule, rows[:2], d)
    b = _fold(rule, rows[2:4], d)
    c = _fold(rule, rows[4:], d)
    for x, y in zip(jax.tree.leaves(rule.merge(rule.merge(a, b), c)),
                    jax.tree.leaves(rule.merge(a, rule.merge(b, c)))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(rule.merge(rule.init(d), a)),
                    jax.tree.leaves(a)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(st.sampled_from(["mean", "oracle", "diversefl", "fltrust"]),
       st.integers(min_value=1, max_value=4))
@settings(max_examples=16, deadline=None)
def test_chunk_order_insensitive(name, n_chunks):
    """Folding disjoint chunks and merging in any order finalizes to the
    same delta (fp tolerance; + is commutative in value)."""
    rng = np.random.default_rng(n_chunks)
    d, n = 13, 12
    rule, rows = _bound_rule(name, n, d, rng)
    bounds = np.linspace(0, n, n_chunks + 1).astype(int)
    partials = [_fold(rule, rows[lo:hi], d)
                for lo, hi in zip(bounds[:-1], bounds[1:]) if hi > lo]
    fwd = partials[0]
    for p in partials[1:]:
        fwd = rule.merge(fwd, p)
    rev = partials[-1]
    for p in reversed(partials[:-1]):
        rev = rule.merge(p, rev)
    d_fwd, _ = rule.finalize(fwd)
    d_rev, _ = rule.finalize(rev)
    np.testing.assert_allclose(np.asarray(d_fwd), np.asarray(d_rev),
                               rtol=1e-5, atol=1e-6)


def test_update_matches_merge_of_singleton():
    """update(s, u, c) == merge(s, update(init, u, c)) up to fp rounding —
    the associativity contract between update and merge."""
    rng = np.random.default_rng(3)
    d = 19
    rule, rows = _bound_rule("diversefl", 5, d, rng)
    state = _fold(rule, rows[:4], d)
    via_update, _ = rule.update(state, *rows[4])
    singleton, _ = rule.update(rule.init(d), *rows[4])
    via_merge = rule.merge(state, singleton)
    _assert_states_close(via_update, via_merge, rtol=1e-6, atol=1e-7)


def test_stream_aggregate_matches_dense_masked_mean():
    """The sweep itself (pad + valid + fold) against the canonical dense
    reduction, bitwise, at a non-divisible chunk."""
    rng = np.random.default_rng(1)
    n, d = 37, 29
    U = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    byz = jnp.asarray(rng.random(n) < 0.3)
    rule = get_streaming("oracle").bind(AggregationContext(byz_mask=byz))

    def block_fn(blk, valid):
        u_blk, byz_b = blk
        return u_blk, {"byz": byz_b}

    delta, _, clogs = stream_aggregate(rule, block_fn, (U, byz), 8, d=d)
    want = masked_mean_flat(U, ~byz)
    np.testing.assert_array_equal(np.asarray(delta), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(clogs["mask"]),
                                  np.asarray(~byz))


# ----------------------------------------------------------------------
# chunked_vmap edge cases (satellite): N < chunk, N % chunk != 0
# ----------------------------------------------------------------------

def test_chunked_vmap_n_smaller_than_chunk():
    """chunk >= N must be *exactly* the vmap path (same traced graph)."""
    xs = jnp.arange(15.0).reshape(5, 3)
    fn = lambda row: (row * row, jnp.sum(row))
    want = jax.vmap(fn)(xs)
    for chunk in (5, 6, 100):
        got = chunked_vmap(fn, (xs,), chunk)
        for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_pad_to_blocks_rejects_chunk_over_c():
    """The shared partition helper fails loudly instead of with an opaque
    reshape error when a new consumer forgets the chunk >= C clamp."""
    from repro.fl.chunking import pad_to_blocks
    with pytest.raises(ValueError, match="exceeds the leading axis"):
        pad_to_blocks((jnp.ones((3, 2)),), 8)


@pytest.mark.parametrize("n,chunk", [(7, 3), (7, 4), (7, 6), (5, 2), (1, 3)])
def test_chunked_vmap_non_divisible_pytree(n, chunk):
    """Padded blocks with pytree args and multi-output fn: padding rows
    never reach the output, rows stay aligned."""
    xs = {"a": jnp.arange(float(n * 3)).reshape(n, 3),
          "b": jnp.arange(float(n)) * 0.5}
    fn = lambda t: {"s": jnp.sum(t["a"]) + t["b"], "v": t["a"] * 2.0}
    want = jax.vmap(fn)(xs)
    got = chunked_vmap(fn, (xs,), chunk)
    for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
