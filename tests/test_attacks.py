"""core/attacks unit coverage (ISSUE 5 satellites): the Byzantine-mask
builder's edge cases and keyed-permutation path, and the unified
scaling branch behind the ``backdoor``/``scale`` attack kinds."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attacks import (AttackConfig, attack_update,
                                make_byzantine_mask)

N = 23


def test_byzantine_mask_f0_is_all_benign():
    mask = make_byzantine_mask(N, 0)
    assert mask.shape == (N,) and mask.dtype == jnp.bool_
    assert int(mask.sum()) == 0


def test_byzantine_mask_f_equals_n_is_all_byzantine():
    mask = make_byzantine_mask(N, N)
    assert int(mask.sum()) == N


def test_byzantine_mask_count_and_determinism():
    for f in (1, 5, 11, N - 1):
        a, b = make_byzantine_mask(N, f), make_byzantine_mask(N, f)
        assert int(a.sum()) == f          # linspace ids must stay distinct
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_byzantine_mask_keyed_permutation():
    """The keyed path permutes identities: same count, deterministic per
    key, and (for a key where the permutation moves bits) different
    placement than the evenly-spaced default."""
    f = 5
    base = make_byzantine_mask(N, f)
    k1 = make_byzantine_mask(N, f, key=jax.random.PRNGKey(0))
    k1b = make_byzantine_mask(N, f, key=jax.random.PRNGKey(0))
    k2 = make_byzantine_mask(N, f, key=jax.random.PRNGKey(7))
    assert int(k1.sum()) == f
    assert np.array_equal(np.asarray(k1), np.asarray(k1b))
    moved = [k for k in (k1, k2)
             if not np.array_equal(np.asarray(k), np.asarray(base))]
    assert moved, "neither keyed permutation moved any Byzantine identity"


def test_byzantine_mask_keyed_f0_and_fn_degenerate():
    """Permutation of an all-False / all-True mask is itself."""
    key = jax.random.PRNGKey(3)
    assert int(make_byzantine_mask(N, 0, key=key).sum()) == 0
    assert int(make_byzantine_mask(N, N, key=key).sum()) == N


# ----------------------------------------------------------------------
# attack_update scaling branch (backdoor == scale) + traced magnitudes
# ----------------------------------------------------------------------

def test_backdoor_and_scale_kinds_share_scaling():
    cfg = AttackConfig(kind="backdoor", scale=5.0)
    u = jnp.arange(8, dtype=jnp.float32) - 3.0
    key = jax.random.PRNGKey(0)
    bd = attack_update(u, "backdoor", key, cfg)
    sc = attack_update(u, "scale", key, cfg)
    np.testing.assert_array_equal(np.asarray(bd), np.asarray(sc))
    np.testing.assert_array_equal(np.asarray(bd), np.asarray(u) * 5.0)


def test_attack_update_operand_overrides_match_config_constants():
    """A traced f32 magnitude operand must reproduce the baked
    Python-float constant bit-for-bit under jit — the scenario-operand
    contract the sweep engine batches on (fl/sweep.py).  Both sides are
    jitted: eager-vs-jit is a different (fusion/FMA) question, and no
    path mixes the two."""
    cfg = AttackConfig(kind="gaussian", sigma=0.3, scale=2.5)
    u = jnp.linspace(-1.0, 1.0, 16, dtype=jnp.float32)
    key = jax.random.PRNGKey(4)
    for kind in ("gaussian", "same_value", "scale", "backdoor", "sign_flip"):
        baked = jax.jit(
            lambda u, kind=kind: attack_update(u, kind, key, cfg))(u)
        traced = jax.jit(
            lambda u, s, c, kind=kind: attack_update(u, kind, key, cfg,
                                                     sigma=s, scale=c))(
            u, jnp.float32(cfg.sigma), jnp.float32(cfg.scale))
        np.testing.assert_array_equal(np.asarray(baked), np.asarray(traced),
                                      err_msg=kind)
