"""Optimizers, schedules, checkpointing, sharding rules, HLO parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.launch import hlo
from repro.optim import (adam_init, adam_step, apply_update, constant_lr,
                         inv_sqrt_lr, sgd_init, sgd_step, step_decay_lr,
                         warmup_then_step_lr)
from repro.sharding import param_partition_spec


def test_sgd_plain_and_momentum():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 2.0)}
    p1, s = sgd_step(p, g, sgd_init(p), lr=0.1)
    np.testing.assert_allclose(p1["w"], 0.8)
    st = sgd_init(p, momentum=0.9)
    p2, st = sgd_step(p, g, st, lr=0.1, momentum=0.9)
    p3, st = sgd_step(p2, g, st, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(p3["w"], 1.0 - 0.2 - 0.1 * (0.9 * 2 + 2))


def test_adam_converges_on_quadratic():
    p = {"w": jnp.asarray(5.0)}
    st = adam_init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st = adam_step(p, g, st, lr=0.1)
    assert abs(float(p["w"])) < 0.1


def test_apply_update():
    p = {"w": jnp.ones((2,))}
    u = {"w": jnp.full((2,), 0.5)}
    out = apply_update(p, u)
    np.testing.assert_allclose(out["w"], 0.5)


def test_schedules():
    assert float(constant_lr(0.1)(100)) == pytest.approx(0.1)
    assert float(inv_sqrt_lr(0.001)(4)) == pytest.approx(0.0005)
    s = step_decay_lr(0.06, [500, 950], 0.5)
    assert float(s(1)) == pytest.approx(0.06)
    assert float(s(500)) == pytest.approx(0.03)
    assert float(s(950)) == pytest.approx(0.015)
    w = warmup_then_step_lr(0.05, 0.1, 1000, [2000], 0.4)
    assert float(w(0)) == pytest.approx(0.05)
    assert float(w(1000)) == pytest.approx(0.1)
    assert float(w(2000)) == pytest.approx(0.04)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": [jnp.ones(2)] ,
            "c": {"d": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 3, tree)
    save_checkpoint(str(tmp_path), 10, tree)
    assert latest_step(str(tmp_path)) == 10
    rec, step = restore_checkpoint(str(tmp_path))
    assert step == 10
    np.testing.assert_allclose(rec["a"], tree["a"])
    assert int(rec["c"]["d"]) == 7


def test_partition_rules():
    assert param_partition_spec("groups/0/attn/wq", 3) == P(None, None, "model")
    assert param_partition_spec("groups/0/attn/wo", 3) == P(None, "model", None)
    assert param_partition_spec("groups/0/mlp/w_up", 3) == P(None, None, "model")
    assert param_partition_spec("groups/0/moe/routed_up", 4) == P(None, "model", None, None)
    assert param_partition_spec("embed", 2) == P("model", None)
    assert param_partition_spec("groups/0/ln1/scale", 2) == P()
    assert param_partition_spec("groups/0/mamba/in_proj", 3) == P(None, None, "model")


def test_hlo_collective_parser():
    text = """
  %all-reduce.1 = bf16[16,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag = (f32[4,8]{1,0}, f32[4,8]{1,0}) all-gather-start(%y, %z), dims={0}
  %nope = f32[2] add(%a, %b)
  %a2a.3 = f32[128]{0} all-to-all(%w), dimensions={0}
"""
    stats = hlo.collective_stats(text)
    assert stats["all-reduce"]["count"] == 1
    assert stats["all-reduce"]["result_bytes"] == 16 * 1024 * 2
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["result_bytes"] == 2 * 4 * 8 * 4
    assert stats["all-to-all"]["count"] == 1
    total = hlo.total_collective_bytes(text)
    assert total == 2 * 16 * 1024 * 2 + 256 + 512


def test_roofline_terms():
    cost = {"flops": 197e12, "bytes accessed": 819e9}
    r = hlo.roofline_terms(cost, collective_bytes=150e9 * 3)
    assert r["t_compute"] == pytest.approx(1.0)
    assert r["t_memory"] == pytest.approx(1.0)
    assert r["t_collective"] == pytest.approx(3.0)
    assert r["dominant"] == "collective"
