"""Tensor-sharded federated rounds (ISSUE 9, DESIGN.md §12).

The contract tested here: the client x model 2D sharding of the
compiled engine — the blocked ``(ms, L)`` flat layout
(sharding.flatten_updates_sharded / ravel_sharded), the shape-generic
streaming fold over it, and the degrade-gracefully gates around it —
is *layout only*:

  * the blocked builders preserve every element (unravel round-trips
    bitwise; at ``ms == 1`` the element order IS the historical ravel
    order);
  * the streaming rules' statistics reduce over all flat model dims
    (``stat_sum``), so the monoid laws hold for the blocked state shape
    exactly as for the classic ``(D,)`` one;
  * a ``model=1`` mesh reproduces the meshless engine history
    **bitwise**; a non-trivial model axis reproduces it to fp tolerance
    (the §12 bounded-ULP relaxation at the Eq. 6 reductions);
  * the one-dispatch contract survives the 2D mesh: one
    ``host_sync`` per run, model axis or not;
  * FLConfig.validate_model_sharding rejects incompatible knob
    combinations with named errors;
  * fl/metrics.comm_stats prices per-shard wire formats from metadata
    alone (pure host arithmetic — no device gather).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import FLConfig, weighted_mean_rule
from repro.fl.compression import get_codec, wire_bytes
from repro.fl.metrics import comm_stats
from repro.fl.streaming import flat_ndim, stat_sum
from repro.sharding import flatten_updates_sharded, ravel_sharded

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(n=3):
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 4)
    return {
        "wq": jax.random.normal(ks[0], (n, 2, 4, 8)),
        "embed": jax.random.normal(ks[1], (n, 6, 4)),
        "norm_b": jax.random.normal(ks[2], (n, 5)),      # odd size: pads
        "w_down": jax.random.normal(ks[3], (n, 2, 8, 4)),
    }


# ----------------------------------------------------------------------
# blocked layout: meshless (ms == 1) invariants
# ----------------------------------------------------------------------

def test_blocked_flatten_ms1_matches_historical_ravel_order():
    """Without a model mesh the blocked build is (n, 1, D) in exactly
    the historical flatten_updates element order."""
    from repro.core.aggregators import flatten_updates
    upd = _tree()
    blk, unravel = flatten_updates_sharded(upd)
    ref, _ = flatten_updates(upd)
    assert blk.shape == (3, 1, ref.shape[1])
    assert np.array_equal(np.asarray(blk[:, 0, :]), np.asarray(ref))


def test_blocked_unravel_roundtrip_bitwise():
    upd = _tree()
    blk, unravel = flatten_updates_sharded(upd)
    out = unravel(blk[1])
    assert set(out) == set(upd)
    for k in upd:
        assert np.array_equal(np.asarray(out[k]), np.asarray(upd[k][1])), k


def test_ravel_sharded_matches_stacked_builder():
    upd = _tree()
    one = {k: v[0] for k, v in upd.items()}
    blk, _ = flatten_updates_sharded(upd)
    vec = ravel_sharded(one)
    assert np.array_equal(np.asarray(vec), np.asarray(blk[0]))


def test_stat_sum_is_last_axis_sum_meshless():
    assert flat_ndim() == 1
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 7))
    assert np.array_equal(np.asarray(stat_sum(x)),
                          np.asarray(jnp.sum(x, axis=-1)))
    v = jax.random.normal(jax.random.PRNGKey(2), (7,))
    assert np.array_equal(np.asarray(stat_sum(v)),
                          np.asarray(jnp.sum(v, axis=-1)))


def test_weighted_mean_rule_init_accepts_blocked_shape():
    """The monoid identity for the blocked layout is zeros((ms, L)) —
    the shape-tuple form of init (classic int d is unchanged)."""
    rule = weighted_mean_rule(
        lambda u, ci: (jnp.ones(jnp.shape(u)[:u.ndim - 1], jnp.float32),) * 2
        + ({},))
    s1, n1 = rule.init(12)
    s2, n2 = rule.init((4, 3))
    assert s1.shape == (12,) and s2.shape == (4, 3)
    assert n1.shape == () and n2.shape == ()


# ----------------------------------------------------------------------
# FLConfig validation: named errors for incompatible knobs
# ----------------------------------------------------------------------

def _base_cfg(**kw):
    kw.setdefault("n_clients", 4)
    kw.setdefault("f", 1)
    kw.setdefault("rounds", 2)
    kw.setdefault("batch_size", 2)
    kw.setdefault("l2", 0.0)
    kw.setdefault("aggregator", "diversefl")
    kw.setdefault("streaming", True)
    return FLConfig(**kw)


def test_validate_model_sharding_noop_at_ms1():
    _base_cfg(streaming=False).validate_model_sharding(100, 1)


def test_validate_model_sharding_rejects_dense():
    with pytest.raises(ValueError, match="streaming"):
        _base_cfg(streaming=False).validate_model_sharding(100, 2)


def test_validate_model_sharding_rejects_fallback_rule():
    with pytest.raises(ValueError, match="cannot stream"):
        _base_cfg(aggregator="median").validate_model_sharding(
            100, 2, streaming_fallback="order statistics")


def test_validate_model_sharding_rejects_kernels():
    with pytest.raises(ValueError, match="use_kernel_agg"):
        _base_cfg(use_kernel_agg=True).validate_model_sharding(100, 2)
    # diversefl + streaming rejects use_kernel_stats at construction
    # already; fltrust reaches the model-sharding check
    with pytest.raises(ValueError, match="use_kernel_stats"):
        _base_cfg(aggregator="fltrust",
                  use_kernel_stats=True).validate_model_sharding(100, 2)


def test_validate_model_sharding_rejects_padded_lossy_leaves():
    with pytest.raises(ValueError, match="pad-free"):
        _base_cfg(compression="bf16").validate_model_sharding(
            128, 2, leaf_sizes=(64, 63, 1))


def test_validate_model_sharding_rejects_qblock_mismatch():
    codec = get_codec("int8")
    assert codec.qblock is not None
    d = codec.qblock * 3          # local shard d/2 not a qblock multiple
    with pytest.raises(ValueError, match="QBLOCK"):
        _base_cfg(compression="int8").validate_model_sharding(
            d, 2, leaf_sizes=(d,))


def test_validate_model_sharding_accepts_compatible_lossy():
    codec = get_codec("int8")
    d = codec.qblock * 4
    _base_cfg(compression="int8").validate_model_sharding(
        d, 2, leaf_sizes=(d // 2, d // 2))


# ----------------------------------------------------------------------
# comm_stats: per-shard wire pricing is host metadata arithmetic
# ----------------------------------------------------------------------

def test_comm_stats_model_shards_prices_local_slices():
    cfg = _base_cfg(compression="int8")
    codec = get_codec("int8")
    d = 1000
    s1 = comm_stats(cfg, d, model_shards=1)
    s4 = comm_stats(cfg, d, model_shards=4)
    assert s1["uplink_bytes_per_client"] == wire_bytes(codec, d)
    assert s4["uplink_bytes_per_client"] == 4 * wire_bytes(codec, 250)
    # uneven split: 2 shards of 334, 1 of 333... (1000 = 3*333 + 1)
    s3 = comm_stats(cfg, d, model_shards=3)
    assert s3["uplink_bytes_per_client"] == (
        2 * wire_bytes(codec, 333) + 1 * wire_bytes(codec, 334))
    assert s4["downlink_bytes_per_round"] == s1["downlink_bytes_per_round"]


# ----------------------------------------------------------------------
# the client x model mesh: subprocess with 8 forced host devices
# ----------------------------------------------------------------------

def test_model_mesh_engine_subprocess():
    """On a forced-8-device host: (a) a model=1 mesh reproduces the
    meshless engine history bitwise; (b) a 4x2 client x model mesh runs
    the same training to fp tolerance with model_shards == 2; (c) the
    blocked layout round-trips bitwise under the live mesh and the
    fold's monoid laws hold for the blocked state; (d) the one-dispatch
    path still syncs exactly once on the 2D mesh."""
    script = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.attacks import AttackConfig
    from repro.fl import (FLConfig, RoundEngine, run_federated_training,
                          make_zoo_federation, zoo_model)
    from repro.fl import simulator as sim
    from repro.models.config import ModelConfig
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import (flatten_updates_sharded, model_shard_count,
                                use_mesh)

    assert len(jax.devices()) == 8, jax.devices()

    mc = ModelConfig(name="zoo-tiny", n_layers=2, d_model=32, n_heads=4,
                     n_kv_heads=2, d_ff=64, vocab_size=64,
                     attn_direct_max=16)
    model = zoo_model(mc, seq_len=8)
    cfg = FLConfig(n_clients=4, f=1, rounds=4, local_steps=1, batch_size=4,
                   l2=0.0, aggregator="diversefl", streaming=True,
                   stream_shards=1, eval_every=2, seed=0, compression="f32",
                   attack=AttackConfig(kind="sign_flip"))
    fed = make_zoo_federation(model, cfg, per_client=16, n_test=32)

    syncs = {"n": 0}
    orig_sync = sim.host_sync
    def counting(tree):
        syncs["n"] += 1
        return orig_sync(tree)
    sim.host_sync = counting

    def run(mesh):
        syncs["n"] = 0
        eng = RoundEngine(model, fed, cfg, mesh=mesh)
        h = run_federated_training(model, fed, cfg, lambda r: 0.05,
                                   engine=eng)
        return eng, h, syncs["n"]

    e0, h0, n0 = run(None)
    e1, h1, n1 = run(make_host_mesh(data=4, model=1))
    e2, h2, n2 = run(make_host_mesh(data=4, model=2))
    assert (e0.model_shards, e1.model_shards, e2.model_shards) == (1, 1, 2)
    assert n0 == n1 == n2 == 1, (n0, n1, n2)   # one-dispatch holds at ms=2

    for k in ("acc", "c1c2", "mask_tpr", "mask_fpr"):
        assert np.array_equal(np.asarray(h0[k]), np.asarray(h1[k])), k
    a0, a2 = np.asarray(h0["acc"]), np.asarray(h2["acc"])
    assert np.allclose(a0, a2, rtol=0, atol=0.08), (a0, a2)

    # blocked layout under the live mesh: shape, round-trip, monoid
    mesh = make_host_mesh(data=4, model=2)
    upd = {"wq": jnp.arange(2 * 2 * 4 * 8, dtype=jnp.float32
                            ).reshape(2, 2, 4, 8),
           "norm_b": jnp.arange(2 * 5, dtype=jnp.float32).reshape(2, 5)}
    with use_mesh(mesh):
        ms = model_shard_count()
        assert ms == 2

        blk, unravel = flatten_updates_sharded(upd)
        assert blk.ndim == 3 and blk.shape[1] == 2
        out = unravel(blk[1])
        for k in upd:
            assert np.array_equal(np.asarray(out[k]),
                                  np.asarray(upd[k][1])), k

        from repro.fl.streaming import stat_sum, flat_ndim
        assert flat_ndim() == 2
        # per-client stats reduce BOTH flat dims -> scalars
        s = stat_sum(blk[0] * blk[0])
        assert s.shape == ()
        sb = stat_sum(blk * blk)
        assert sb.shape == (2,)
        assert np.allclose(np.asarray(sb[0]), np.asarray(s))

        # monoid laws on the blocked state (exact: integer values)
        from repro.fl import weighted_mean_rule
        rule = weighted_mean_rule(
            lambda u, ci: (jnp.ones(jnp.shape(u)[:u.ndim - 2],
                                    jnp.float32),) * 2 + ({},))
        d = blk.shape[1:]
        s0 = rule.init(d)
        sA, _ = rule.update(s0, blk[0], {})
        sAB, _ = rule.update(sA, blk[1], {})
        sB, _ = rule.update(rule.init(d), blk[1], {})
        merged = rule.merge(sA, sB)
        for x, y in zip(jax.tree.leaves(sAB), jax.tree.leaves(merged)):
            assert np.array_equal(np.asarray(x), np.asarray(y))
        delta, _ = rule.finalize(sAB)
        assert delta.shape == d
        ref = (np.asarray(blk[0]) + np.asarray(blk[1])) / 2.0
        assert np.array_equal(np.asarray(delta), ref)
    print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert p.returncode == 0, \
        f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-4000:]}"
    assert "OK" in p.stdout
