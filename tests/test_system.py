"""End-to-end behaviour of the paper's system (Sec. IV reproduced at test
scale): DiverseFL matches OracleSGD and detects every attack family, while
undefended aggregation collapses; sample-poisoning screening drops
poisoned clients; RSA trains; the paper-scale NN setting works."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.core.sample_filter import (FilterConfig, pretrain_clean_model,
                                      screen_clients)
from repro.data import (FederatedData, make_mnist_like,
                        partition_sorted_shards)
from repro.fl import (FLConfig, Federation, mlp3, run_federated_training,
                      softmax_regression)
from repro.fl.metrics import backdoor_accuracy, main_task_accuracy
from repro.optim import inv_sqrt_lr

N_CLIENTS, F = 23, 5
ROUNDS = 60


@pytest.fixture(scope="module")
def mnist_fed_data():
    x, y = make_mnist_like(jax.random.PRNGKey(0), 4600)
    tx, ty = make_mnist_like(jax.random.PRNGKey(9), 800)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), 10)
    return data, tx, ty


def _run(data, tx, ty, aggregator, attack, rounds=ROUNDS, model=None, **kw):
    model = model or softmax_regression()
    kw.setdefault("f", F)
    cfg = FLConfig(n_clients=N_CLIENTS, rounds=rounds,
                   aggregator=aggregator, attack=attack, batch_size=50,
                   eval_every=rounds, **kw)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    hist = run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))
    return hist, fed, model


@pytest.mark.parametrize("attack", ["sign_flip", "gaussian", "same_value",
                                    "label_flip"])
def test_diversefl_matches_oracle_under_attacks(mnist_fed_data, attack):
    data, tx, ty = mnist_fed_data
    acfg = AttackConfig(kind=attack, sigma=1e4)
    h_dfl, _, _ = _run(data, tx, ty, "diversefl", acfg)
    h_orc, _, _ = _run(data, tx, ty, "oracle", acfg)
    assert h_dfl["final_acc"] >= h_orc["final_acc"] - 0.03, attack
    # detection is perfect on these attacks (paper Fig. 2)
    assert h_dfl["mask_tpr"][-1] == 1.0
    assert h_dfl["mask_fpr"][-1] == 0.0


def test_undefended_mean_collapses_under_gaussian(mnist_fed_data):
    data, tx, ty = mnist_fed_data
    acfg = AttackConfig(kind="gaussian", sigma=1e4)
    h_mean, _, _ = _run(data, tx, ty, "mean", acfg)
    h_dfl, _, _ = _run(data, tx, ty, "diversefl", acfg)
    assert h_dfl["final_acc"] > h_mean["final_acc"] + 0.3


def test_no_attack_no_false_positives(mnist_fed_data):
    data, tx, ty = mnist_fed_data
    h, _, _ = _run(data, tx, ty, "diversefl", AttackConfig(kind="none"),
                   f=0)
    assert h["final_acc"] > 0.9
    assert h["mask_fpr"][-1] == 0.0


def test_many_byzantine_clients_f17(mnist_fed_data):
    """Appendix B-1: DiverseFL works for f=17 of 23 (~75% Byzantine)."""
    data, tx, ty = mnist_fed_data
    acfg = AttackConfig(kind="sign_flip")
    model = softmax_regression()
    cfg = FLConfig(n_clients=N_CLIENTS, f=17, rounds=ROUNDS,
                   aggregator="diversefl", attack=acfg, batch_size=50,
                   eval_every=ROUNDS)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    h = run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))
    assert h["mask_tpr"][-1] == 1.0
    assert h["final_acc"] > 0.5  # still learns from the 6 benign clients


def test_backdoor_mitigation_nn(mnist_fed_data):
    """Fig. 7: model-replacement backdoor breaches FLTrust-style weighted
    aggregation but not DiverseFL."""
    data, tx, ty = mnist_fed_data
    acfg = AttackConfig(kind="backdoor", scale=5.0, source_class=3,
                        target_class=4)
    h_dfl, fed, model = _run(data, tx, ty, "diversefl", acfg)
    bd = backdoor_accuracy(model, h_dfl["params"], tx, ty, acfg)
    main = main_task_accuracy(model, h_dfl["params"], tx, ty, acfg)
    assert bd < 0.3, f"backdoor succeeded: {bd}"
    assert main > 0.8
    h_mean, fed2, model2 = _run(data, tx, ty, "mean", acfg)
    bd_mean = backdoor_accuracy(model2, h_mean["params"], tx, ty, acfg)
    # undefended aggregation never admits less backdoor (on the easy
    # synthetic task both can end at ~0; the hard claims are the DiverseFL
    # bd < 0.3 and main > 0.8 asserts above)
    assert bd_mean >= bd


def test_multiple_local_iterations(mnist_fed_data):
    """Appendix B-2: DiverseFL keeps working with E>1 local steps."""
    data, tx, ty = mnist_fed_data
    acfg = AttackConfig(kind="sign_flip")
    h, _, _ = _run(data, tx, ty, "diversefl", acfg, local_steps=3,
                   rounds=40)
    assert h["mask_tpr"][-1] == 1.0
    assert h["final_acc"] > 0.9


def test_nn_training_mlp(mnist_fed_data):
    """Sec. IV-B analogue at test scale: 3-NN under label flip."""
    data, tx, ty = mnist_fed_data
    acfg = AttackConfig(kind="label_flip")
    h, _, _ = _run(data, tx, ty, "diversefl", acfg, rounds=50,
                   model=mlp3(), l2=0.0005)
    assert h["final_acc"] > 0.85
    assert h["mask_tpr"][-1] >= 0.8


def test_partial_participation(mnist_fed_data):
    """Sec. II-A: the server selects |S^i| = C <= N clients per round;
    DiverseFL's per-client criteria work on whichever subset shows up."""
    data, tx, ty = mnist_fed_data
    h, _, _ = _run(data, tx, ty, "diversefl", AttackConfig(kind="sign_flip"),
                   rounds=50, participation=0.5)
    assert h["final_acc"] > 0.85
    assert h["mask_fpr"][-1] <= 0.1  # selection shrinks batches -> tiny FP rate ok


def test_stealthy_scale_attack_c2_band(mnist_fed_data):
    """x1.5-scaled updates sit inside the (0.5, 2) band by length but are
    caught only when the band is tightened — the C2 ablation story."""
    data, tx, ty = mnist_fed_data
    from repro.core.diversefl import DiverseFLConfig
    acfg = AttackConfig(kind="scale", scale=3.0)
    h, _, _ = _run(data, tx, ty, "diversefl", acfg, rounds=30)
    # x3 exceeds eps3=2 -> caught by condition 2
    assert h["mask_tpr"][-1] == 1.0


def test_sample_poisoning_screen(mnist_fed_data):
    """Sec. IV-C: poisoned shared samples are detected by the pre-trained
    clean model and those clients are dropped from the enclave."""
    data, tx, ty = mnist_fed_data
    model = softmax_regression()
    cfg = FLConfig(n_clients=N_CLIENTS, f=8, aggregator="diversefl",
                   attack=AttackConfig(kind="label_flip"))
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))

    # 8 clients share label-flipped samples
    byz_ids = [int(i) for i in np.where(np.asarray(fed.byz_mask))[0]]
    for cid in byz_ids:
        x, yy = fed.enclave.unseal_samples(cid)
        fed.enclave.seal_samples(cid, x, 9 - yy)

    fcfg = FilterConfig(threshold=0.7)
    clean_x, clean_y = make_mnist_like(jax.random.PRNGKey(77), 1000)
    pre = pretrain_clean_model(model, clean_x, clean_y, fcfg,
                               jax.random.PRNGKey(5))
    accepted, accs = screen_clients(model, pre, fed.enclave, fcfg)
    assert set(accepted).isdisjoint(byz_ids)
    assert len(accepted) == N_CLIENTS - 8
