"""Per-architecture smoke tests (required deliverable f): for each of the
10 assigned architectures, instantiate the REDUCED same-family variant and
run one forward + one train step on CPU, asserting output shapes and the
absence of NaNs.  Decode-capable archs also run one decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, models

ARCH_IDS = configs.all_arch_ids()


def _batch(cfg, key, B=2, S=32):
    b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_enc_dec:
        b["enc_emb"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.02
    elif cfg.has_cross:
        b["cross_emb"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32) * 0.02
    return b


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_reduced_variant(arch_id):
    cfg = configs.get(arch_id, smoke=True)
    assert cfg.d_model <= 512 and cfg.n_experts <= 4
    params = models.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    # forward: hidden state shape + finite
    out = models.apply(params, cfg, batch["tokens"],
                       enc_emb=batch.get("enc_emb"),
                       cross_emb=batch.get("cross_emb"))
    B, S = batch["tokens"].shape
    assert out["hidden"].shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(out["hidden"].astype(jnp.float32)).any())

    # one SGD train step: loss decreases or at least grads are finite
    loss_fn = lambda p: models.loss_fn(p, cfg, batch)
    l0, g = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    for leaf in jax.tree.leaves(g):
        assert not bool(jnp.isnan(leaf.astype(jnp.float32)).any())
    params2 = jax.tree.map(
        lambda p, gg: (p.astype(jnp.float32) - 0.1 * gg.astype(jnp.float32)
                       ).astype(p.dtype), params, g)
    l1 = loss_fn(params2)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0) + 0.5  # step did not explode


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_step(arch_id):
    cfg = configs.get(arch_id, smoke=True)
    params = models.init(jax.random.PRNGKey(0), cfg)
    B = 2
    cache = models.init_cache(cfg, B, cache_len=64)
    tok = jnp.ones((B, 1), jnp.int32)
    lg, cache = models.decode_step(params, cfg, tok, cache, jnp.int32(0))
    assert lg.shape == (B, 1, cfg.padded_vocab)
    # pad logits are masked so decode can never emit a padding token
    assert int(jnp.argmax(lg, -1).max()) < cfg.vocab_size
    lg, cache = models.decode_step(params, cfg, tok, cache, jnp.int32(1))
    assert not bool(jnp.isnan(lg).any())


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_instantiates_abstractly(arch_id):
    """FULL configs are exercised via eval_shape only (no allocation)."""
    cfg = configs.get(arch_id)
    shapes = jax.eval_shape(
        lambda: models.init(jax.random.PRNGKey(0), cfg))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    assert total > 0.5e9  # every assigned arch is >0.5B params


EXPECTED_PARAMS_B = {
    "gemma-2b": (2.2, 2.8),
    "whisper-medium": (0.6, 0.9),
    "deepseek-moe-16b": (14, 18),
    "kimi-k2-1t-a32b": (950, 1100),
    "h2o-danube-1-8b": (1.5, 2.0),
    "granite-20b": (18, 22),
    "llama-3-2-vision-90b": (80, 95),
    "jamba-v0-1-52b": (46, 56),
    "minitron-8b": (6, 9),
    "falcon-mamba-7b": (6.3, 7.8),
}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_counts_match_model_names(arch_id):
    cfg = configs.get(arch_id)
    lo, hi = EXPECTED_PARAMS_B[arch_id]
    n = cfg.param_count() / 1e9
    assert lo <= n <= hi, f"{arch_id}: {n:.2f}B not in [{lo},{hi}]"
