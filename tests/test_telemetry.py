"""Flight recorder: spans/events, on-device round telemetry, audit chain
(ISSUE 8, DESIGN.md §11).

Contracts:

  * **disabled == free and silent** — no records, spans pass through,
    instrumented code paths unchanged.
  * **the audit chain binds** — every entry commits to its predecessor's
    digest; mutation, reordering, truncation-from-the-middle and forged
    prev-links are all detected, naming the first bad entry.
  * **SecureServer wires the log** — attestation, seals, guide-cache
    rebuilds and round tags appear as chained entries.
  * **the telemetry block matches the memory model** —
    ``metrics.round_telemetry_bytes`` == 4 bytes × the field count
    ``make_round_telemetry_fn`` actually emits for that config.
  * **telemetry does not perturb training** — histories bitwise-equal
    on/off (the sync-count half lives in tests/test_dispatch_eval.py).
  * **export/load roundtrip** — JSONL out, identical records + audit in.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.data import (FederatedData, make_classification,
                        partition_sorted_shards)
from repro.fl import (FLConfig, Federation, run_federated_training,
                      softmax_regression, telemetry, trace_counter)
from repro.fl.engine import TRACE_COUNTS
from repro.fl.metrics import round_telemetry_bytes
from repro.fl.telemetry import (AuditLog, GENESIS, Recorder,
                                make_round_telemetry_fn, verify_entries)
from repro.optim import inv_sqrt_lr

N_CLIENTS, DIM, N_CLASSES = 12, 8, 3


@pytest.fixture(scope="module")
def fed_data():
    x, y = make_classification(jax.random.PRNGKey(0), N_CLIENTS * 8,
                               N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, N_CLASSES, DIM)
    return data, tx, ty


def _cfg(**kw):
    kw.setdefault("n_clients", N_CLIENTS)
    kw.setdefault("f", 3)
    kw.setdefault("rounds", 4)
    kw.setdefault("batch_size", 2)
    kw.setdefault("eval_every", 2)
    kw.setdefault("l2", 0.0)
    kw.setdefault("attack", AttackConfig(kind="sign_flip"))
    return FLConfig(**kw)


def _train(fed_data, cfg):
    data, tx, ty = fed_data
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    return run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05)), fed


# ----------------------------------------------------------------------
# Recorder: spans + events
# ----------------------------------------------------------------------

def test_disabled_recorder_is_silent():
    rec = Recorder()
    rec.event("x", a=1)
    with rec.span("s"):
        pass
    assert rec.records == [] and not rec.enabled
    # the module-level API is equally inert outside recording()
    telemetry.event("orphan")
    with telemetry.span("orphan"):
        pass
    assert not telemetry.enabled()


def test_spans_nest_and_events_interleave():
    with telemetry.recording() as rec:
        with rec.span("outer", n=2):
            rec.event("tick", i=0)
            with rec.span("inner"):
                rec.event("tick", i=1)
    assert not rec.enabled                       # recording() stopped it
    kinds = [(r["type"], r.get("name") or r.get("kind")) for r in rec.records]
    # spans append at exit: inner closes before outer
    assert kinds == [("event", "tick"), ("event", "tick"),
                     ("span", "inner"), ("span", "outer")]
    inner = rec.records[2]
    outer = rec.records[3]
    assert inner["depth"] == 1 and outer["depth"] == 0
    assert outer["t0"] <= inner["t0"] and inner["t1"] <= outer["t1"]
    assert outer["n"] == 2
    assert rec.counts() == {"event:tick": 2, "span:inner": 1,
                            "span:outer": 1}


def test_recording_resets_between_uses():
    with telemetry.recording() as rec:
        rec.event("a")
    with telemetry.recording() as rec2:
        rec2.event("b")
    assert [r["kind"] for r in rec2.records] == ["b"]


# ----------------------------------------------------------------------
# trace_counter: the supported compile-count API
# ----------------------------------------------------------------------

def test_trace_counter_scoped_and_nested():
    with trace_counter() as outer:
        TRACE_COUNTS["segment"] += 2             # simulate two traces
        with trace_counter() as inner:
            TRACE_COUNTS["training"] += 1
        assert inner.snapshot() == {"segment": 0, "training": 1, "eval": 0}
        assert outer["segment"] == 2             # live read inside the block
    assert outer.total() == 3
    # the globals keep counting — the API never resets them
    assert TRACE_COUNTS["segment"] >= 2


# ----------------------------------------------------------------------
# AuditLog: the hash chain binds
# ----------------------------------------------------------------------

def _chain(n=5):
    log = AuditLog()
    for i in range(n):
        log.append("step", i=i)
    return log


def test_audit_chain_verifies_and_heads():
    log = AuditLog()
    assert log.head == GENESIS and bool(log.verify())
    log.append("attestation", measurement="m")
    log.append("seal", client=0)
    v = log.verify()
    assert v and v.entries == 2
    assert log.entries[0]["prev"] == GENESIS
    assert log.entries[1]["prev"] == log.entries[0]["digest"]
    assert log.head == log.entries[1]["digest"]
    assert log.counts() == {"attestation": 1, "seal": 1}


def test_audit_mutation_detected():
    entries = [dict(e) for e in _chain().entries]
    entries[2] = dict(entries[2], data={"i": 99})
    v = verify_entries(entries)
    assert not v and v.bad_index == 2 and "mutated" in v.reason


def test_audit_reorder_detected():
    entries = [dict(e) for e in _chain().entries]
    entries[1], entries[2] = entries[2], entries[1]
    assert not verify_entries(entries)


def test_audit_middle_deletion_detected():
    entries = [dict(e) for e in _chain().entries]
    del entries[2]
    assert not verify_entries(entries)
    # truncation from the END is *not* detectable from the list alone —
    # that is what committing the head digest elsewhere is for
    assert verify_entries(_chain().entries[:3])


def test_audit_forged_tail_detected():
    log = _chain(3)
    forged = dict(log.entries[-1])
    forged = {**forged, "index": 3, "data": {"i": 3}, "prev": "f" * 64}
    assert not verify_entries(log.entries + [forged])


def test_audit_malformed_entry_reported():
    v = verify_entries([{"kind": "x"}])
    assert not v and "malformed" in v.reason


# ----------------------------------------------------------------------
# SecureServer wiring
# ----------------------------------------------------------------------

def test_secure_server_audits_lifecycle(fed_data):
    cfg = _cfg(telemetry=True)
    h, fed = _train(fed_data, cfg)
    kinds = fed.server.audit.counts()
    assert kinds["attestation"] == 1
    assert kinds["seal"] == N_CLIENTS
    assert kinds["guide_cache_rebuild"] >= 1
    assert kinds["round_tags"] == cfg.rounds
    assert fed.server.audit.verify()
    tags = [e for e in fed.server.audit.entries if e["kind"] == "round_tags"]
    assert [e["data"]["round"] for e in tags] == [1, 2, 3, 4]
    for e in tags:
        assert e["data"]["kept"] + e["data"]["tagged"] == N_CLIENTS
    # drop after training extends the same chain
    fed.server.drop_client(0)
    assert fed.server.audit.verify()
    assert fed.server.audit.entries[-1]["kind"] == "drop"


def test_telemetry_off_appends_no_round_tags(fed_data):
    _, fed = _train(fed_data, _cfg())
    assert "round_tags" not in fed.server.audit.counts()
    assert fed.server.audit.verify()


# ----------------------------------------------------------------------
# the on-device block: fields, values, memory model
# ----------------------------------------------------------------------

def test_round_telemetry_fn_matches_reference():
    cfg = _cfg(telemetry=True)
    tel_fn = make_round_telemetry_fn(cfg)
    n = 6
    k = jax.random.PRNGKey(0)
    dot = jax.random.normal(k, (n,))
    z_sq = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,))) + 0.1
    g_sq = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,))) + 0.1
    from repro.core.diversefl import criterion_logs, diversefl_mask
    mask = diversefl_mask(dot, z_sq, g_sq, cfg.dfl)
    logs = {"mask": mask, "z_sq": z_sq, "g_sq": g_sq,
            **criterion_logs(dot, z_sq, g_sq)}
    t = jax.jit(tel_fn)(logs)                      # jittable by contract
    mask_np = np.asarray(mask)
    assert int(t["kept"]) == mask_np.sum()
    assert int(t["tagged"]) == n - mask_np.sum()
    assert int(t["c1_pass"]) == (np.asarray(dot) > 0).sum()
    c2 = np.asarray(logs["c2"])
    assert int(t["c2_pass"]) == ((c2 > cfg.dfl.eps2)
                                 & (c2 < cfg.dfl.eps3)).sum()
    np.testing.assert_allclose(float(t["upd_norm_mean"]),
                               np.sqrt(np.asarray(z_sq)).mean(), rtol=1e-6)
    np.testing.assert_allclose(float(t["guide_norm_max"]),
                               np.sqrt(np.asarray(g_sq)).max(), rtol=1e-6)


@pytest.mark.parametrize("agg,log_keys", [
    ("diversefl", ("mask", "c1", "c2", "c1c2", "z_sq", "g_sq")),
    ("oracle", ("mask",)),
    ("mean", ()),
])
def test_round_telemetry_bytes_matches_fn(agg, log_keys):
    """The §11 memory model and the actual block agree field-for-field:
    4 bytes per emitted scalar, independent of N."""
    cfg = _cfg(aggregator=agg, telemetry=True)
    logs = {k: jnp.ones((N_CLIENTS,)) for k in log_keys}
    fields = len(make_round_telemetry_fn(cfg)(logs))
    assert round_telemetry_bytes(cfg) == 4 * fields


# ----------------------------------------------------------------------
# end-to-end: bitwise histories, fallback reporting, export/load
# ----------------------------------------------------------------------

def test_histories_bitwise_with_telemetry(fed_data):
    h_off, _ = _train(fed_data, _cfg())
    with telemetry.recording():
        h_on, _ = _train(fed_data, _cfg(telemetry=True))
    assert h_off["round"] == h_on["round"]
    for k in ("acc", "mask_tpr", "mask_fpr", "final_acc"):
        assert np.array_equal(np.asarray(h_off[k]), np.asarray(h_on[k])), k
    for a, b in zip(h_off["c1c2"], h_on["c1c2"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    flat = lambda p: np.concatenate(                            # noqa: E731
        [np.asarray(v).ravel() for v in jax.tree.leaves(p)])
    assert np.array_equal(flat(h_off["params"]), flat(h_on["params"]))


def test_streaming_fallback_reported_in_history(fed_data):
    # median cannot stream -> the reason lands on the history now, not
    # just the engine instance (ISSUE 8 satellite)
    h, _ = _train(fed_data, _cfg(aggregator="median", streaming=True,
                                 rounds=2))
    assert isinstance(h["streaming_fallback"], str)
    h2, _ = _train(fed_data, _cfg(rounds=2))
    assert h2["streaming_fallback"] is None


def test_export_load_roundtrip(tmp_path, fed_data):
    path = tmp_path / "run.jsonl"
    with telemetry.recording() as rec:
        h, fed = _train(fed_data, _cfg(telemetry=True))
        telemetry.export_jsonl(path, recorder=rec, audit=fed.server.audit,
                               meta={"suite": "test"})
    run = telemetry.load_jsonl(path)
    assert run["header"]["schema"] == telemetry.SCHEMA_VERSION
    assert run["header"]["meta"] == {"suite": "test"}
    assert verify_entries(run["audit"])
    assert run["audit"] == [
        {k: e[k] for k in ("index", "kind", "data", "prev", "digest")}
        for e in fed.server.audit.entries]
    assert len([e for e in run["events"] if e["kind"] == "sync"]) == 1
    assert len([e for e in run["events"] if e["kind"] == "round"]) == 4
    names = [s["name"] for s in run["spans"]]
    assert "run_training" in names and "dispatch" in names


def test_observe_cli_renders_and_verifies(tmp_path, fed_data, capsys):
    from repro.launch import observe

    path = tmp_path / "run.jsonl"
    with telemetry.recording() as rec:
        h, fed = _train(fed_data, _cfg(telemetry=True))
        telemetry.export_jsonl(path, recorder=rec, audit=fed.server.audit)
    assert observe.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "span waterfall" in out and "round timeline" in out
    assert "VERIFIED" in out
    assert observe.main([str(path), "--summary"]) == 0
    # a tampered file exits non-zero
    lines = path.read_text().splitlines()
    import json
    for i, line in enumerate(lines):
        rec_l = json.loads(line)
        if rec_l.get("type") == "audit" and rec_l["kind"] == "round_tags":
            rec_l["data"]["kept"] = 999
            lines[i] = json.dumps(rec_l)
            break
    bad = tmp_path / "tampered.jsonl"
    bad.write_text("\n".join(lines) + "\n")
    assert observe.main([str(bad)]) == 1
    assert "BROKEN" in capsys.readouterr().out
