"""Hierarchical two-tier aggregation (ISSUE 6, DESIGN.md §9).

The contract tested here: the two-tier fold — P contiguous pod-major
block groups, each folded with the PR 3 left fold (S-way shard-parallel
within the pod), tier-1 partials combined per pod and the P per-pod
AggStates combined across pods, both by ``tree_merge``'s canonical
balanced-binary association — is a **pure function of (client order,
chunk, S, pods)**:

  * ``pods=1`` *is* the single-tier fold — bitwise (delta AND
    per-client logs), for every streaming rule, because P <= 1 routes
    through the identical code path;
  * per-client criterion logs are bitwise at every (S, pods) — neither
    tier's association touches per-row statistics;
  * depth-2 monoid laws: merging the per-pod partials of a pod-order
    permutation reproduces the canonical result on exact data, and the
    merge of pod partials equals the flat fold bitwise when every add
    is exact (0/1 weights, integer updates);
  * executing the same P-way fold under an active ("pod", "data",
    "model") mesh matches the meshless fold (subprocess, forced host
    devices) — placement cannot change the association;
  * the shard-by-shard segment batch staging
    (data/pipeline.segment_minibatches + sharding/api.
    put_clients_by_shard) is bitwise-equal to the one-shot build.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.data import (FederatedData, make_classification,
                        partition_sorted_shards)
from repro.fl import (FLConfig, Federation, run_federated_training,
                      softmax_regression, stream_aggregate, tree_merge)
from repro.fl.chunking import group_blocks_2d, resolve_pods
from repro.fl.server import AggregationContext
from repro.fl.streaming import get_streaming
from repro.optim import inv_sqrt_lr
from repro.sharding import ShardMismatchError
from repro.fl.sweep import SweepSpec, group_cells, structural_key

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_CLIENTS, DIM, N_CLASSES = 64, 8, 4
RULES = ["mean", "oracle", "diversefl", "fltrust"]


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def _bound(name, n, d, rng):
    U = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    byz = jnp.asarray(rng.random(n) < 0.3)
    root = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    rule = get_streaming(name).bind(
        AggregationContext(byz_mask=byz, guides=G, root_update=root))

    def block_fn(blk, valid):
        u_blk, g_blk, byz_b = blk
        return u_blk, {"byz": byz_b, "guide": g_blk}

    return rule, block_fn, (U, G, byz)


# ----------------------------------------------------------------------
# the fold itself: stream_aggregate at pods ∈ {1, 2, 4} per rule
# ----------------------------------------------------------------------

@pytest.mark.parametrize("name", RULES)
def test_pods_one_is_single_tier_bitwise(name):
    """P <= 1 routes through the verbatim single-tier code path: delta
    AND logs bitwise, with and without an explicit shard count."""
    rng = np.random.default_rng(0)
    n, d, chunk = 32, 23, 4
    rule, block_fn, args = _bound(name, n, d, rng)
    d_seq, _, logs_seq = stream_aggregate(rule, block_fn, args, chunk, d=d)
    for kw in ({"pods": 1}, {"pods": 1, "shards": 2}):
        ref = stream_aggregate(rule, block_fn, args, chunk, d=d,
                               shards=kw.get("shards"))
        got = stream_aggregate(rule, block_fn, args, chunk, d=d, **kw)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(ref[0]))
        for a, b in zip(jax.tree.leaves(got[2]), jax.tree.leaves(ref[2])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", RULES)
@pytest.mark.parametrize("pods,shards", [(2, None), (4, None), (2, 2)])
def test_two_tier_per_client_logs_bitwise(name, pods, shards):
    """Neither tier's merge touches per-row statistics: per-client
    criterion logs are bitwise at every (S, pods); the delta reassembles
    through log2(P)+log2(S) merge adds -> tight fp tolerance."""
    rng = np.random.default_rng(1)
    n, d, chunk = 32, 23, 4
    rule, block_fn, args = _bound(name, n, d, rng)
    d_seq, _, logs_seq = stream_aggregate(rule, block_fn, args, chunk, d=d)
    d_p, _, logs_p = stream_aggregate(rule, block_fn, args, chunk, d=d,
                                      pods=pods, shards=shards)
    for a, b in zip(jax.tree.leaves(logs_seq), jax.tree.leaves(logs_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_seq),
                               rtol=1e-5, atol=1e-6)


def test_two_tier_deterministic_per_pod_count():
    rng = np.random.default_rng(2)
    n, d, chunk = 32, 17, 4
    rule, block_fn, args = _bound("diversefl", n, d, rng)
    a = stream_aggregate(rule, block_fn, args, chunk, d=d, pods=4)[0]
    b = stream_aggregate(rule, block_fn, args, chunk, d=d, pods=4)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# depth-2 monoid laws on exact data
# ----------------------------------------------------------------------

def _exact_oracle(rng, n, d):
    U = jnp.asarray(rng.integers(-8, 8, size=(n, d)).astype(np.float32))
    byz = jnp.asarray(rng.random(n) < 0.3)
    rule = get_streaming("oracle").bind(AggregationContext(byz_mask=byz))

    def block_fn(blk, valid):
        u_blk, byz_b = blk
        return u_blk, {"byz": byz_b}

    return rule, block_fn, (U, byz)


def test_exact_data_two_tier_equals_flat_bitwise():
    """With integer updates and 0/1 weights every add is exact, so the
    merge of pod partials reproduces the flat fold bit for bit at every
    (pods, shards) — both tiers change association, never math."""
    rng = np.random.default_rng(3)
    n, d, chunk = 32, 11, 2
    rule, block_fn, args = _exact_oracle(rng, n, d)
    ref = np.asarray(stream_aggregate(rule, block_fn, args, chunk, d=d)[0])
    for pods, shards in [(2, None), (4, None), (8, None), (2, 2), (4, 2)]:
        got = stream_aggregate(rule, block_fn, args, chunk, d=d,
                               pods=pods, shards=shards)[0]
        np.testing.assert_array_equal(np.asarray(got), ref)


def test_exact_data_pod_order_insensitive_under_canonical_association():
    """Depth-2 law: fold each pod's clients separately, merge the
    stacked per-pod partials with tree_merge — on exact data any pod
    permutation yields the same state (the monoid is commutative and
    every add exact), and the result matches the two-tier fold."""
    rng = np.random.default_rng(4)
    n, d, chunk, P = 32, 11, 2, 4
    rule, block_fn, (U, byz) = _exact_oracle(rng, n, d)
    per = n // P

    def pod_partial(p):
        lo, hi = p * per, (p + 1) * per
        # fold ONE pod's clients from the identity — tier 1 in isolation
        state = rule.init(d)
        for i in range(lo, hi):
            state, _ = rule.update(state, U[i], {"byz": byz[i]})
        return state

    parts = [pod_partial(p) for p in range(P)]
    ref = np.asarray(stream_aggregate(rule, block_fn, (U, byz), chunk,
                                      d=d, pods=P)[0])
    for order in ([0, 1, 2, 3], [3, 2, 1, 0], [2, 0, 3, 1]):
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[parts[i] for i in order])
        delta, _ = rule.finalize(tree_merge(rule.merge, stacked, P))
        np.testing.assert_array_equal(np.asarray(delta), ref)


# ----------------------------------------------------------------------
# partitioning primitives: resolve_pods / group_blocks_2d
# ----------------------------------------------------------------------

def test_resolve_pods_auto_clamps_explicit_raises():
    assert resolve_pods(None, 8, auto=4) == 4
    assert resolve_pods(None, 8, auto=3) == 2    # clamp like resolve_shards
    assert resolve_pods(None, 7, auto=4) == 1
    assert resolve_pods(2, 8) == 2
    with pytest.raises(ShardMismatchError, match="must divide"):
        resolve_pods(3, 8)
    with pytest.raises(ShardMismatchError, match="must divide"):
        resolve_pods(16, 8)
    with pytest.raises(ShardMismatchError, match=">= 1"):
        resolve_pods(0, 8)


def test_group_blocks_2d_shape_and_order():
    """(k, ...) -> (pods, shards, k/(P·S), ...) with pod-major,
    shard-contiguous block order — the layout the ("pod", "data")
    client placement produces."""
    k, P, S = 8, 2, 2
    blocks = jnp.arange(k * 3.0).reshape(k, 3)
    g = group_blocks_2d(blocks, k, P, S)
    assert g.shape == (P, S, k // (P * S), 3)
    np.testing.assert_array_equal(
        np.asarray(g.reshape(k, 3)), np.asarray(blocks))
    assert float(g[1, 0, 0, 0]) == float(blocks[4, 0])  # pod 1 starts at k/P


def test_group_blocks_2d_divisibility_errors():
    blocks = jnp.zeros((6, 2))
    with pytest.raises(ShardMismatchError, match="must divide"):
        group_blocks_2d(blocks, 6, 4, 1)
    with pytest.raises(ShardMismatchError, match="must divide"):
        group_blocks_2d(blocks, 6, 2, 2)


# ----------------------------------------------------------------------
# training level: FLConfig.pods
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def fed_data():
    x, y = make_classification(jax.random.PRNGKey(0), N_CLIENTS * 8,
                               N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, N_CLASSES, DIM)
    return data, tx, ty


def _train(fed_data, **kw):
    data, tx, ty = fed_data
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    kw.setdefault("n_clients", N_CLIENTS)
    kw.setdefault("f", 12)
    kw.setdefault("rounds", 2)
    kw.setdefault("batch_size", 2)
    kw.setdefault("eval_every", 2)
    kw.setdefault("l2", 0.0)
    kw.setdefault("client_chunk", 8)
    kw.setdefault("streaming", True)
    kw.setdefault("attack", AttackConfig(kind="sign_flip"))
    cfg = FLConfig(**kw)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    return run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))


@pytest.mark.parametrize("aggregator", RULES)
def test_training_pods_one_is_single_tier(fed_data, aggregator):
    h_seq = _train(fed_data, aggregator=aggregator)
    h_p1 = _train(fed_data, aggregator=aggregator, pods=1)
    assert np.array_equal(_flat(h_seq["params"]), _flat(h_p1["params"]))


@pytest.mark.parametrize("pods", [2, 4])
def test_training_pods_close_and_masks_bitwise(fed_data, pods):
    h_seq = _train(fed_data)
    h_p = _train(fed_data, pods=pods)
    np.testing.assert_allclose(_flat(h_p["params"]), _flat(h_seq["params"]),
                               rtol=1e-5, atol=1e-6)
    # keep-mask counts derive from per-row stats -> bitwise at any P
    assert h_seq["mask_tpr"] == h_p["mask_tpr"]
    assert h_seq["mask_fpr"] == h_p["mask_fpr"]


def test_flconfig_pods_validation():
    base = dict(n_clients=N_CLIENTS, f=12, client_chunk=8, streaming=True)
    with pytest.raises(ValueError, match="pods must be None"):
        FLConfig(**base, pods=0)
    with pytest.raises(ValueError, match="requires streaming"):
        FLConfig(n_clients=N_CLIENTS, f=12, client_chunk=8,
                 streaming=False, pods=2)
    with pytest.raises(ValueError, match="requires client_chunk"):
        FLConfig(n_clients=N_CLIENTS, f=12, streaming=True, pods=2)
    with pytest.raises(ValueError, match="cannot tile"):
        FLConfig(**base, pods=3)       # k = 8 blocks, 3 does not divide
    assert FLConfig(**base, pods=2).pods == 2


# ----------------------------------------------------------------------
# sweep: pods is a structural axis — never batched across pod counts
# ----------------------------------------------------------------------

def test_sweep_pods_axis_is_structural():
    base = FLConfig(n_clients=N_CLIENTS, f=12, rounds=2, batch_size=2,
                    eval_every=2, client_chunk=8, streaming=True,
                    attack=AttackConfig(kind="sign_flip"))
    spec = SweepSpec(base=base, seeds=(0, 1), pods=(None, 1, 2))
    cells = spec.cells()
    assert len(cells) == 6
    groups = group_cells(cells)
    # one structural group per pod count: seeds batch, pods never do
    assert len(groups) == 3
    for members in groups.values():
        assert len({c.cfg.pods for _, c in members}) == 1
        assert len(members) == 2       # the two seeds batched together
    assert structural_key(cells[0].cfg) != structural_key(cells[2].cfg)


# ----------------------------------------------------------------------
# mesh execution + shard-by-shard batch staging (forced host devices)
# ----------------------------------------------------------------------

def test_pod_mesh_fold_and_pipeline_bitwise_subprocess():
    """On a forced-8-device host: (a) make_host_pod_mesh builds the
    ("pod", "data", "model") mesh and pod_data_counts sees it; (b) the
    shard-by-shard segment batch staging equals the one-shot build
    bitwise while landing sharded across all devices; (c) training
    under the pod mesh (pods auto-derived) matches the meshless run."""
    script = """
    import numpy as np, jax
    from repro.launch.mesh import make_host_pod_mesh, client_axes, n_clients
    from repro.sharding import (use_mesh, data_shard_count, pod_count,
                                pod_data_counts)
    from repro.data import (FederatedData, make_classification,
                            partition_sorted_shards)
    from repro.data.pipeline import _stacked_minibatches

    mesh = make_host_pod_mesh(pods=4, data=2, model=1)
    assert client_axes(mesh) == ("pod", "data") and n_clients(mesh) == 8

    N, DIM, NC = 16, 6, 4
    x, y = make_classification(jax.random.PRNGKey(0), N * 10, NC, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N), NC)
    keys = jax.vmap(jax.random.PRNGKey)(np.arange(3, dtype=np.uint32))
    with use_mesh(mesh):
        assert data_shard_count() == 8 and pod_count() == 4
        assert pod_data_counts() == (4, 2)
        xb, yb = data.segment_minibatches(keys, 5)
    ref_x, ref_y = _stacked_minibatches(keys, data.x, data.y, 5)
    assert np.array_equal(np.asarray(xb), np.asarray(ref_x))
    assert np.array_equal(np.asarray(yb), np.asarray(ref_y))
    assert len(xb.sharding.device_set) == 8

    from repro.core.attacks import AttackConfig
    from repro.fl import (FLConfig, Federation, run_federated_training,
                          softmax_regression)
    from repro.optim import inv_sqrt_lr
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, NC, DIM)
    model = softmax_regression(input_dim=DIM, n_classes=NC)
    cfg = FLConfig(n_clients=N, f=3, rounds=2, batch_size=2, eval_every=2,
                   l2=0.0, client_chunk=2, streaming=True,
                   attack=AttackConfig(kind="sign_flip"))
    fed0 = Federation.create(model, data, tx, ty, cfg,
                             jax.random.PRNGKey(2))
    h0 = run_federated_training(model, fed0, cfg, inv_sqrt_lr(0.05))
    with use_mesh(mesh):
        fed = Federation.create(model, data, tx, ty, cfg,
                                jax.random.PRNGKey(2))
        h = run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))
    flat = lambda p: np.concatenate([np.asarray(v).ravel()
                                     for v in jax.tree.leaves(p)])
    assert np.allclose(flat(h["params"]), flat(h0["params"]),
                       rtol=1e-5, atol=1e-6)
    assert h["mask_tpr"] == h0["mask_tpr"]
    assert h["mask_fpr"] == h0["mask_fpr"]
    print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert p.returncode == 0, \
        f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-4000:]}"
    assert "OK" in p.stdout


def test_host_pod_mesh_insufficient_devices_named_error():
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        from repro.launch.mesh import make_host_pod_mesh
        make_host_pod_mesh(pods=64, data=64, model=64)
