"""One-dispatch training: in-scan device eval (ISSUE 4, DESIGN.md §7).

Contracts:

  * **in-scan eval == host-loop eval, bitwise** — the one-dispatch path
    (eval folded into the outer scan, one host sync) reproduces the
    legacy per-segment host-eval loop and the seed per-round loop
    bit-for-bit: params, every metric history, every eval round index —
    including partial participation, backdoor attacks (main-task +
    backdoor accuracy), streaming aggregation, and a final partial
    segment when ``rounds % eval_every != 0``.
  * **metrics are jittable where-masked reductions** — no boolean
    indexing, no ``float()`` casts: the same function jits, returns
    device scalars, and matches a NumPy reference computed with the
    seed's dynamic-shape indexing semantics.
  * **the host sync is one, and counted** — every device→host
    materialization flows through ``repro.fl.simulator.host_sync``; a
    multi-segment run syncs exactly once on the one-dispatch path and
    once per segment on the legacy path.
  * **the donate knob threads** — FLConfig.donate → RoundEngine,
    tri-state (None = backend auto).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl.simulator as sim
from repro.core.attacks import AttackConfig
from repro.data import (FederatedData, make_classification,
                        partition_sorted_shards)
from repro.fl import (FLConfig, Federation, RoundEngine,
                      run_federated_training, softmax_regression)
from repro.fl.metrics import (accuracy, backdoor_accuracy, make_backdoor_eval,
                              main_task_accuracy, mask_rates, masked_accuracy,
                              stamp_trigger)
from repro.optim import inv_sqrt_lr

N_CLIENTS, DIM, N_CLASSES = 32, 16, 4


@pytest.fixture(scope="module")
def fed_data():
    x, y = make_classification(jax.random.PRNGKey(0), N_CLIENTS * 8,
                               N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 128, N_CLASSES, DIM)
    return data, tx, ty


def _cfg(**kw):
    kw.setdefault("n_clients", N_CLIENTS)
    kw.setdefault("f", 6)
    kw.setdefault("rounds", 6)
    kw.setdefault("batch_size", 2)
    kw.setdefault("eval_every", 3)
    kw.setdefault("l2", 0.0)
    kw.setdefault("attack", AttackConfig(kind="sign_flip"))
    return FLConfig(**kw)


def _train(fed_data, cfg, **kw):
    data, tx, ty = fed_data
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    return run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05), **kw)


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def _assert_histories_bitwise(a, b):
    assert a["round"] == b["round"]
    for k in ("acc", "main_acc", "backdoor_acc", "mask_tpr", "mask_fpr"):
        assert a.get(k, []) == b.get(k, []), k
    for ca, cb in zip(a.get("c1c2", []), b.get("c1c2", [])):
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    assert np.array_equal(_flat(a["params"]), _flat(b["params"]))


# ----------------------------------------------------------------------
# in-scan eval == host-loop eval == seed loop: bitwise
# ----------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    {},                                                    # divisible rounds
    {"rounds": 7},                                         # partial tail seg
    {"participation": 0.5, "rounds": 4},                   # cohort sampling
    {"attack": AttackConfig(kind="backdoor", source_class=1,
                            target_class=2), "rounds": 4},  # backdoor metrics
    {"streaming": True, "client_chunk": 8, "rounds": 4,
     "attack": AttackConfig(kind="gaussian")},             # streaming rounds
])
def test_in_scan_eval_matches_host_loop_bitwise(fed_data, kw):
    cfg = _cfg(**kw)
    h_dev = _train(fed_data, cfg)
    h_host = _train(fed_data, cfg, host_eval=True)
    _assert_histories_bitwise(h_dev, h_host)


def test_in_scan_eval_matches_seed_loop_bitwise(fed_data):
    cfg = _cfg(eval_every=2)
    h_dev = _train(fed_data, cfg)
    h_seed = _train(fed_data, cfg, use_engine=False)
    _assert_histories_bitwise(h_dev, h_seed)


def test_backdoor_history_has_attack_metrics(fed_data):
    cfg = _cfg(attack=AttackConfig(kind="backdoor", source_class=1,
                                   target_class=2), rounds=3)
    h = _train(fed_data, cfg)
    assert len(h["main_acc"]) == len(h["round"])
    assert len(h["backdoor_acc"]) == len(h["round"])
    h_plain = _train(fed_data, _cfg(rounds=3))
    assert "main_acc" not in h_plain or not h_plain["main_acc"]


# ----------------------------------------------------------------------
# metrics: jittable, device scalars, reference semantics
# ----------------------------------------------------------------------

def test_metrics_are_jittable_device_scalars(fed_data):
    data, tx, ty = fed_data
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    params = model.init(jax.random.PRNGKey(1))
    acfg = AttackConfig(kind="backdoor", source_class=1, target_class=2)
    for fn in (lambda p: accuracy(model, p, tx, ty),
               lambda p: main_task_accuracy(model, p, tx, ty, acfg),
               lambda p: backdoor_accuracy(model, p, tx, ty, acfg)):
        eager, jitted = fn(params), jax.jit(fn)(params)
        assert isinstance(eager, jax.Array) and eager.shape == ()
        assert np.asarray(eager) == np.asarray(jitted)


def test_metrics_match_numpy_reference(fed_data):
    """Where-masked reductions == the seed's boolean-indexing semantics."""
    data, tx, ty = fed_data
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    params = model.init(jax.random.PRNGKey(4))
    acfg = AttackConfig(kind="backdoor", source_class=1, target_class=2)
    preds = np.argmax(np.asarray(model.apply(params, tx)), -1)
    ty_np = np.asarray(ty)

    assert np.asarray(accuracy(model, params, tx, ty)) == pytest.approx(
        (preds == ty_np).mean(), abs=1e-6)
    sel = ty_np != acfg.source_class
    assert np.asarray(main_task_accuracy(model, params, tx, ty, acfg)) == \
        pytest.approx((preds[sel] == ty_np[sel]).mean(), abs=1e-6)
    # backdoor: stamp only the source rows (the seed gathered them first)
    xs = np.asarray(tx).copy()
    xs[:, :3] = 1.0
    bd_preds = np.argmax(np.asarray(model.apply(params, jnp.asarray(xs))), -1)
    src = ty_np == acfg.source_class
    want = (bd_preds[src] == acfg.target_class).mean() if src.any() else 0.0
    assert np.asarray(backdoor_accuracy(model, params, tx, ty, acfg)) == \
        pytest.approx(want, abs=1e-6)


def test_masked_accuracy_empty_mask_is_zero():
    model = softmax_regression(input_dim=4, n_classes=3)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.ones((5, 4))
    y = jnp.zeros((5,), jnp.int32)
    out = masked_accuracy(model, params, x, y, jnp.zeros((5,), bool))
    assert np.asarray(out) == 0.0


def test_mask_rates_edge_cases():
    mask = jnp.asarray([True, False, True, False])
    byz = jnp.asarray([False, True, False, True])
    tpr, fpr = mask_rates(mask, byz)
    assert (np.asarray(tpr), np.asarray(fpr)) == (1.0, 0.0)
    # no Byzantine client -> TPR defaults to 1.0; no benign -> FPR 0.0
    tpr, _ = mask_rates(mask, jnp.zeros((4,), bool))
    assert np.asarray(tpr) == 1.0
    _, fpr = mask_rates(mask, jnp.ones((4,), bool))
    assert np.asarray(fpr) == 0.0


def test_stamp_trigger_shapes():
    img = jnp.zeros((2, 8, 8, 3))
    assert np.asarray(stamp_trigger(img))[:, :3, :3].min() == 1.0
    assert np.asarray(stamp_trigger(img))[:, 3:, 3:].max() == 0.0
    flat = jnp.zeros((2, 8))
    assert np.asarray(stamp_trigger(flat))[:, :3].min() == 1.0


def test_federation_backdoor_eval_is_cached(fed_data):
    """The trigger-stamped test set is built once per federation and
    reused; a different source/target pair rebuilds it."""
    data, tx, ty = fed_data
    acfg = AttackConfig(kind="backdoor", source_class=1, target_class=2)
    cfg = _cfg(attack=acfg)
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    ev1 = fed.backdoor_eval(acfg)
    assert fed.backdoor_eval(acfg) is ev1
    ev2 = fed.backdoor_eval(AttackConfig(kind="backdoor", source_class=2,
                                         target_class=3))
    assert ev2 is not ev1 and ev2.source_class == 2
    np.testing.assert_array_equal(
        np.asarray(ev1.x), np.asarray(make_backdoor_eval(tx, ty, acfg).x))


# ----------------------------------------------------------------------
# host syncs: one per run (one-dispatch) vs one per segment (legacy)
# ----------------------------------------------------------------------

def _count_syncs(fed_data, cfg, monkeypatch, **kw):
    counter = {"n": 0}
    orig = sim.host_sync

    def counting(tree):
        counter["n"] += 1
        return orig(tree)

    monkeypatch.setattr(sim, "host_sync", counting)
    h = _train(fed_data, cfg, **kw)
    return counter["n"], h


def test_one_dispatch_syncs_once(fed_data, monkeypatch):
    cfg = _cfg(rounds=6, eval_every=2)          # 3 segments
    n_dev, _ = _count_syncs(fed_data, cfg, monkeypatch)
    assert n_dev == 1
    n_host, _ = _count_syncs(fed_data, cfg, monkeypatch, host_eval=True)
    assert n_host == 3


def test_one_dispatch_under_transfer_guard(fed_data):
    """Nothing on the one-dispatch path reaches the host outside the
    choke point: the whole run executes under a device→host guard."""
    cfg = _cfg(rounds=4, eval_every=2)
    _train(fed_data, cfg)                       # compile outside the guard
    with jax.transfer_guard_device_to_host("disallow_explicit"):
        h = _train(fed_data, cfg)
    assert len(h["acc"]) == 2


def test_telemetry_keeps_single_sync_under_transfer_guard(fed_data,
                                                          monkeypatch):
    """ISSUE 8: the per-round telemetry block rides the existing metric
    buffer — a telemetry-enabled 10-segment run still reaches the host
    in exactly ONE final sync, under the d2h guard, with the history
    bitwise-identical to the telemetry-off run."""
    from repro.fl import telemetry

    cfg = _cfg(rounds=10, eval_every=1, telemetry=True)      # 10 segments
    h_off = _train(fed_data, _cfg(rounds=10, eval_every=1))
    _train(fed_data, cfg)                       # compile outside the guard
    with telemetry.recording() as rec:
        with jax.transfer_guard_device_to_host("disallow_explicit"):
            n, h_on = _count_syncs(fed_data, cfg, monkeypatch)
    assert n == 1
    _assert_histories_bitwise(h_off, h_on)
    # the recorder saw the same single sync, and one record per round
    syncs = [r for r in rec.records if r.get("kind") == "sync"]
    rounds = [r for r in rec.records if r.get("kind") == "round"]
    assert len(syncs) == 1
    assert [r["index"] for r in rounds] == list(range(1, 11))


# ----------------------------------------------------------------------
# donate knob: FLConfig -> RoundEngine, tri-state
# ----------------------------------------------------------------------

def test_donate_knob_threads_through(fed_data):
    data, tx, ty = fed_data
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)

    def engine(**kw):
        cfg = _cfg(**kw)
        fed = Federation.create(model, data, tx, ty, cfg,
                                jax.random.PRNGKey(2))
        return RoundEngine(model, fed, cfg)

    auto = jax.default_backend() != "cpu"
    assert engine().donate is auto              # None -> backend auto
    assert engine(donate=True).donate is True   # forced on (measurement)
    assert engine(donate=False).donate is False


def test_donate_forced_on_still_runs(fed_data):
    """donate=True on CPU compiles and runs (XLA ignores the request);
    the numbers cannot change."""
    cfg_on, cfg_off = _cfg(rounds=4, donate=True), _cfg(rounds=4)
    h_on = _train(fed_data, cfg_on)
    h_off = _train(fed_data, cfg_off)
    assert np.array_equal(_flat(h_on["params"]), _flat(h_off["params"]))
