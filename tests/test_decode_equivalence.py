"""Decode-vs-forward equivalence: running tokens one-by-one through
decode_step with a KV/SSM cache must reproduce the full-sequence forward
logits (the serving-correctness invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.models import ModelConfig

CASES = {
    "dense_gqa": ModelConfig(
        name="d", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=97, attn_direct_max=64, remat=False, dtype="float32",
        param_dtype="float32"),
    "mqa_geglu": ModelConfig(
        name="m", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=97, activation="geglu", attn_direct_max=64, remat=False,
        dtype="float32", param_dtype="float32"),
    "swa_ring": ModelConfig(
        name="s", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=97, layout=(("swa", "mlp"),), window=8,
        attn_direct_max=64, remat=False, dtype="float32",
        param_dtype="float32"),
    "mamba": ModelConfig(
        name="ssm", n_layers=3, d_model=48, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab_size=97, layout=(("mamba", "none"),), ssm_state=8, remat=False,
        dtype="float32", param_dtype="float32"),
    "moe": ModelConfig(
        name="moe", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=97, layout=(("attn", "moe"),), n_experts=4, top_k=2,
        n_shared_experts=1, d_expert=32, capacity_factor=8.0, remat=False,
        dtype="float32", param_dtype="float32"),
}


@pytest.mark.parametrize("case", list(CASES))
def test_decode_matches_forward(case):
    cfg = CASES[case]
    T = 20
    params = models.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0, cfg.vocab_size)

    out = models.apply(params, cfg, toks)
    full_logits = models.logits(params, cfg, out["hidden"])  # (2, T, V)

    cache = models.init_cache(cfg, 2, cache_len=T)
    dec = []
    for t in range(T):
        lg, cache = models.decode_step(params, cfg, toks[:, t:t + 1],
                                       cache, jnp.int32(t))
        dec.append(lg[:, 0])
    dec_logits = jnp.stack(dec, axis=1)

    if case == "swa_ring":
        # ring buffer only holds the window: compare positions where the
        # full forward sees the same window (all positions, since window
        # masking applies to the forward too)
        np.testing.assert_allclose(dec_logits, full_logits, rtol=2e-3, atol=2e-3)
    else:
        np.testing.assert_allclose(dec_logits, full_logits, rtol=2e-3, atol=2e-3)


def test_vlm_decode_uses_cross_cache():
    cfg = ModelConfig(
        name="vlm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=97, layout=(("attn", "mlp"), ("xattn", "mlp")),
        frontend="vision", n_patches=8, remat=False,
        dtype="float32", param_dtype="float32")
    params = models.init(jax.random.PRNGKey(0), cfg)
    T = 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0, 97)
    emb = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 64)) * 0.1

    out = models.apply(params, cfg, toks, cross_emb=emb)
    full_logits = models.logits(params, cfg, out["hidden"])

    # build cache including the cross kv (as prefill would)
    from repro.models.attention import make_cross_kv
    cache = models.init_cache(cfg, 1, cache_len=T)
    groups = list(cache["groups"])
    g_idx = 1  # xattn entry
    xp = jax.tree.map(lambda w: w, params["groups"][g_idx]["xattn"])
    kv = jax.vmap(lambda w: make_cross_kv(emb, w, cfg))(xp)
    groups[g_idx] = {"cross": kv}
    cache["groups"] = tuple(groups)

    dec = []
    for t in range(T):
        lg, cache = models.decode_step(params, cfg, toks[:, t:t + 1],
                                       cache, jnp.int32(t))
        dec.append(lg[:, 0])
    dec_logits = jnp.stack(dec, axis=1)
    np.testing.assert_allclose(dec_logits, full_logits, rtol=2e-3, atol=2e-3)


def test_blockwise_attention_matches_direct():
    """The XLA 'flash' (chunked) path equals direct attention."""
    base = dict(name="x", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab_size=97, remat=False, dtype="float32",
                param_dtype="float32")
    cfg_direct = ModelConfig(**base, attn_direct_max=4096)
    cfg_chunk = ModelConfig(**base, attn_direct_max=16, attn_chunk=32)
    params = models.init(jax.random.PRNGKey(0), cfg_direct)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 100), 0, 97)
    h1 = models.apply(params, cfg_direct, toks)["hidden"]
    h2 = models.apply(params, cfg_chunk, toks)["hidden"]
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)
