"""Non-IID partitioners (Sec. IV-A: sort-by-class sharding; Appendix B-2:
two random shards per client after [3]; plus Dirichlet for ablations)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def partition_sorted_shards(x, y, n_clients: int):
    """Paper's main split: sort by class, cut into n_clients contiguous
    subsets -> each client sees ~1 class (extreme heterogeneity)."""
    order = np.argsort(np.asarray(y), kind="stable")
    xs, ys = np.asarray(x)[order], np.asarray(y)[order]
    per = len(ys) // n_clients
    return [(jnp.asarray(xs[i * per:(i + 1) * per]),
             jnp.asarray(ys[i * per:(i + 1) * per])) for i in range(n_clients)]


def partition_two_shards(x, y, n_clients: int, seed: int = 0,
                         shards_per_client: int = 2):
    """[3]-style: sort by class, cut into 2*N shards, deal each client
    `shards_per_client` random shards (Appendix B-2 setting)."""
    rng = np.random.default_rng(seed)
    order = np.argsort(np.asarray(y), kind="stable")
    xs, ys = np.asarray(x)[order], np.asarray(y)[order]
    n_shards = n_clients * shards_per_client
    per = len(ys) // n_shards
    shard_ids = rng.permutation(n_shards)
    out = []
    for c in range(n_clients):
        ids = shard_ids[c * shards_per_client:(c + 1) * shards_per_client]
        xi = np.concatenate([xs[i * per:(i + 1) * per] for i in ids])
        yi = np.concatenate([ys[i * per:(i + 1) * per] for i in ids])
        out.append((jnp.asarray(xi), jnp.asarray(yi)))
    return out


def partition_dirichlet(x, y, n_clients: int, alpha: float = 0.3,
                        seed: int = 0, n_classes=None):
    """Dirichlet(alpha) label-skew partition (standard non-IID benchmark)."""
    rng = np.random.default_rng(seed)
    y_np = np.asarray(y)
    n_classes = n_classes or int(y_np.max()) + 1
    idx_by_class = [np.where(y_np == c)[0] for c in range(n_classes)]
    client_idx = [[] for _ in range(n_clients)]
    for idxs in idx_by_class:
        rng.shuffle(idxs)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idxs)).astype(int)[:-1]
        for c, part in enumerate(np.split(idxs, cuts)):
            client_idx[c].extend(part.tolist())
    x_np = np.asarray(x)
    return [(jnp.asarray(x_np[np.asarray(ci, int)]),
             jnp.asarray(y_np[np.asarray(ci, int)]))
            for ci in client_idx]
