from .synthetic import (make_classification, make_mnist_like, make_cifar_like,
                        make_token_stream)
from .partition import partition_sorted_shards, partition_dirichlet, partition_two_shards
from .pipeline import ClientDataset, FederatedData, batch_iterator
