"""Synthetic datasets (the container is offline; MNIST/CIFAR are stood in
by class-structured synthetic data with the same shapes and class counts).

`make_mnist_like` / `make_cifar_like` draw each class from its own
anchored random template plus noise, so the task is genuinely learnable
(linear models reach high accuracy, like MNIST) and label-flip /
backdoor attacks behave as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_classification(key, n: int, n_classes: int, dim: int,
                        noise: float = 0.35, template_scale: float = 1.0,
                        template_seed: int = 1234):
    """Gaussian class-template data: x = T[y] + noise * N(0, I).

    Templates are drawn from a *fixed* seed so different calls (train and
    test splits, different clients) share the same class structure."""
    k2, k3 = jax.random.split(key, 2)
    templates = jax.random.normal(
        jax.random.PRNGKey(template_seed + dim), (n_classes, dim)) * template_scale
    y = jax.random.randint(k2, (n,), 0, n_classes)
    x = templates[y] + noise * jax.random.normal(k3, (n, dim))
    return x.astype(jnp.float32), y.astype(jnp.int32)


def make_mnist_like(key, n: int = 6900, n_classes: int = 10):
    x, y = make_classification(key, n, n_classes, 28 * 28, noise=0.5)
    return x.reshape(n, 28, 28), y


def make_cifar_like(key, n: int = 6900, n_classes: int = 10):
    x, y = make_classification(key, n, n_classes, 32 * 32 * 3, noise=0.6)
    return x.reshape(n, 32, 32, 3), y


def make_token_stream(key, n_seqs: int, seq_len: int, vocab: int,
                      zipf_a: float = 1.2):
    """Zipf-ish synthetic token data for the LLM architectures: a mixture
    of per-sequence topic distributions so there is learnable structure."""
    k1, k2 = jax.random.split(key)
    # sample per-sequence topic shift, then zipf ranks
    u = jax.random.uniform(k1, (n_seqs, seq_len), minval=1e-6, maxval=1.0)
    ranks = jnp.floor(jnp.exp(jnp.log(u) / (-zipf_a + 1e-9))) % vocab
    shift = jax.random.randint(k2, (n_seqs, 1), 0, vocab)
    return ((ranks.astype(jnp.int32) + shift) % vocab).astype(jnp.int32)
