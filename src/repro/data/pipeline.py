"""Federated data pipeline: per-client datasets padded to a common size so
the whole federation stacks into (N, n_i, ...) arrays and client training
can be vmapped; plus the once-before-training enclave sample draw (Step 1).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import (client_put, data_shard_count, get_mesh,
                        put_clients_by_shard)


@dataclasses.dataclass
class ClientDataset:
    x: jnp.ndarray
    y: jnp.ndarray

    @property
    def n(self) -> int:
        return int(self.y.shape[0])


@dataclasses.dataclass
class FederatedData:
    """Stacked federation: x (N, n, ...), y (N, n); n = min client size."""
    x: jnp.ndarray
    y: jnp.ndarray
    n_classes: int

    @property
    def n_clients(self) -> int:
        return int(self.y.shape[0])

    @property
    def per_client(self) -> int:
        return int(self.y.shape[1])

    @classmethod
    def from_partitions(cls, parts: List[Tuple[jnp.ndarray, jnp.ndarray]],
                        n_classes: int):
        n = min(int(p[1].shape[0]) for p in parts)
        x = jnp.stack([p[0][:n] for p in parts])
        y = jnp.stack([p[1][:n] for p in parts])
        return cls(x=x, y=y, n_classes=n_classes)

    def minibatch(self, key, batch_size: int):
        """One random mini-batch per client: (N, m, ...), (N, m)."""
        keys = jax.random.split(key, self.n_clients)

        def take(k, xs, ys):
            idx = jax.random.randint(k, (batch_size,), 0, self.per_client)
            return xs[idx], ys[idx]
        return jax.vmap(take)(keys, self.x, self.y)

    def segment_minibatches(self, keys, batch_size: int):
        """Minibatch stacks for one scan segment of the round engine.

        ``keys``: (T, 2) — one ``kb`` subkey per round, derived by the
        engine with the same chain the per-round path uses, so row t is
        bit-identical to ``minibatch(keys[t], batch_size)``.  Returns
        ``(T, N, m, ...), (T, N, m)`` with the client axis (dim 1)
        placed on the mesh's data axes when one is active
        (sharding/api.client_put) — batch data for a sharded segment
        lives distributed from the start instead of being scattered by
        the first round's constraint.

        With a mesh that splits the client axis more than one way the
        stack is built **shard by shard** (DESIGN.md §9): each client
        shard's rows are sampled independently — from the same
        per-client subkeys the one-shot build derives, so the bits are
        identical — placed directly on that shard's device, and the
        global array assembled from the per-device pieces
        (sharding/api.put_clients_by_shard).  No single host buffer
        ever holds the full ``(T, N, m, ...)`` stack, which is what
        lets a multi-pod federation stage batch stacks whose union
        exceeds one host's memory.
        """
        mesh = get_mesh()
        N = self.n_clients
        if mesh is not None and data_shard_count(mesh) > 1 \
                and N % data_shard_count(mesh) == 0:
            T = int(keys.shape[0])
            ckeys = _client_round_keys(keys, N)
            built = {}      # one sample per client range; replicas reuse it

            def build(lo, hi):
                if (lo, hi) not in built:
                    built[(lo, hi)] = _take_minibatches(
                        ckeys[:, lo:hi], self.x[lo:hi], self.y[lo:hi],
                        batch_size)
                return built[(lo, hi)]

            xshape = (T, N, batch_size) + tuple(self.x.shape[2:])
            yshape = (T, N, batch_size)
            xb = put_clients_by_shard(lambda lo, hi: build(lo, hi)[0],
                                      xshape, axis=1, mesh=mesh)
            yb = put_clients_by_shard(lambda lo, hi: build(lo, hi)[1],
                                      yshape, axis=1, mesh=mesh)
            return xb, yb
        xb, yb = _stacked_minibatches(keys, self.x, self.y, batch_size)
        return client_put(xb, axis=1), client_put(yb, axis=1)

    def enclave_samples(self, key, frac: float):
        """Step 1: uniform sample M_j^0 (size s = frac * n_j) per client."""
        s = max(1, int(self.per_client * frac))
        keys = jax.random.split(key, self.n_clients)

        def take(k, xs, ys):
            idx = jax.random.choice(k, self.per_client, (s,), replace=False)
            return xs[idx], ys[idx]
        return jax.vmap(take)(keys, self.x, self.y)


@functools.partial(jax.jit, static_argnames=("n",))
def _client_round_keys(keys, n: int):
    """(T, 2) round keys -> (T, n, 2) per-client subkeys: exactly the
    ``jax.random.split(k, n)`` every round of ``minibatch`` performs,
    precomputed so the shard-by-shard build can slice client ranges out
    of the *same* key matrix the one-shot build consumes."""
    return jax.vmap(lambda k: jax.random.split(k, n))(keys)


@functools.partial(jax.jit, static_argnames=("batch_size",))
def _take_minibatches(ckeys, x, y, batch_size: int):
    """(T, C, 2) per-client subkeys + (C, per_client, ...) client data ->
    (T, C, m, ...), (T, C, m) stacks.  Per-(round, client) draws are
    independent (one randint + one gather each), so building a client
    *slice* is bit-identical to slicing the full build — the invariant
    the shard-by-shard segment staging rests on."""
    per_client = y.shape[1]

    def take(kc, xs, ys):
        idx = jax.random.randint(kc, (batch_size,), 0, per_client)
        return xs[idx], ys[idx]

    return jax.vmap(lambda ks: jax.vmap(take)(ks, x, y))(ckeys)


@functools.partial(jax.jit, static_argnames=("batch_size",))
def _stacked_minibatches(keys, x, y, batch_size: int):
    """(T, 2) round keys -> (T, N, m, ...), (T, N, m) minibatch stacks.

    Row t is bit-identical to ``FederatedData.minibatch(keys[t], m)``
    (same key split, same randint draw); jitted so serving a segment is
    one cached dispatch rather than a fresh eager-vmap trace."""
    return _take_minibatches(_client_round_keys(keys, y.shape[0]),
                             x, y, batch_size)


def batch_iterator(key, x, y, batch_size: int):
    n = y.shape[0]
    while True:
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch_size,), 0, n)
        yield x[idx], y[idx]
