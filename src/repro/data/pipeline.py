"""Federated data pipeline: per-client datasets padded to a common size so
the whole federation stacks into (N, n_i, ...) arrays and client training
can be vmapped; plus the once-before-training enclave sample draw (Step 1).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ClientDataset:
    x: jnp.ndarray
    y: jnp.ndarray

    @property
    def n(self) -> int:
        return int(self.y.shape[0])


@dataclasses.dataclass
class FederatedData:
    """Stacked federation: x (N, n, ...), y (N, n); n = min client size."""
    x: jnp.ndarray
    y: jnp.ndarray
    n_classes: int

    @property
    def n_clients(self) -> int:
        return int(self.y.shape[0])

    @property
    def per_client(self) -> int:
        return int(self.y.shape[1])

    @classmethod
    def from_partitions(cls, parts: List[Tuple[jnp.ndarray, jnp.ndarray]],
                        n_classes: int):
        n = min(int(p[1].shape[0]) for p in parts)
        x = jnp.stack([p[0][:n] for p in parts])
        y = jnp.stack([p[1][:n] for p in parts])
        return cls(x=x, y=y, n_classes=n_classes)

    def minibatch(self, key, batch_size: int):
        """One random mini-batch per client: (N, m, ...), (N, m)."""
        keys = jax.random.split(key, self.n_clients)

        def take(k, xs, ys):
            idx = jax.random.randint(k, (batch_size,), 0, self.per_client)
            return xs[idx], ys[idx]
        return jax.vmap(take)(keys, self.x, self.y)

    def enclave_samples(self, key, frac: float):
        """Step 1: uniform sample M_j^0 (size s = frac * n_j) per client."""
        s = max(1, int(self.per_client * frac))
        keys = jax.random.split(key, self.n_clients)

        def take(k, xs, ys):
            idx = jax.random.choice(k, self.per_client, (s,), replace=False)
            return xs[idx], ys[idx]
        return jax.vmap(take)(keys, self.x, self.y)


def batch_iterator(key, x, y, batch_size: int):
    n = y.shape[0]
    while True:
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch_size,), 0, n)
        yield x[idx], y[idx]
