"""Scan-compiled, chunked, mesh-sharded federated round engine.

The seed simulator dispatched one jitted call per round and vmapped
client local training over the *entire* federation, so (i) every round
paid a Python dispatch + host sync and (ii) peak memory was
O(N x model) — N capped at what one device holds.  The engine removes
both limits while keeping the round math — Algorithm 1 Steps 2-5 —
byte-identical to the per-round path:

  * **Scan segmentation** — ``eval_every`` rounds compile into a single
    donated ``jax.lax.scan``: one dispatch and one host sync per eval
    segment.  Per-round RNG subkeys and learning rates are precomputed
    host-side with exactly the legacy ``key, sub = split(key)`` chain,
    so the scan consumes the same key sequence the Python loop would.
  * **Client chunking** — local training and guiding updates run in
    ``client_chunk``-sized blocks via ``jax.lax.map``
    (fl/chunking.chunked_vmap), so a 1000-client federation peaks at
    O(chunk x model) working memory while still producing the stacked
    (N, D) update matrix the aggregator registry expects.  Guides are
    threaded through ``SecureServer.compute_guides`` — the enclave stays
    the only source of guide data.
  * **Client-axis sharding** — when a mesh is active the client axis of
    the stacked batches/updates is sharded over the ``("data",)`` axes
    via sharding/api.py NamedShardings, unifying the simulator's
    semantics with launch/train.py's one-client-per-mesh-coordinate
    shard_map path.

``make_round_body`` is the single round-step definition: the legacy
per-round path (fl/simulator.py, the benchmark baseline) jits it
directly; the engine scans it.  Equivalence is enforced by
tests/test_engine.py.
"""
from __future__ import annotations

import contextlib
import functools
import logging
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import aggregators as agg
from ..core.attacks import (UPDATE_ATTACKS, attack_update, flip_labels,
                            make_byzantine_mask, poison_backdoor)
from ..sharding import (flatten_updates_sharded, get_mesh,
                        model_shard_count, place_params, ravel_sharded,
                        shard_clients, shard_flat, shard_params,
                        shard_updates, sweep_put, use_mesh)
from . import telemetry
from .chunking import chunked_vmap
from .compression import encode_with_feedback, get_codec
from .faults import (corrupt_updates, draw_faults, init_async_state,
                     make_cohort_chain, validate_cohort_chain)
from .metrics import make_eval_fn
from .server import AggregationContext, get_aggregator
from .streaming import fallback_reason, get_streaming, stream_aggregate

logger = logging.getLogger(__name__)


# ----------------------------------------------------------------------
# Scenario operands — the per-run values that are data, not structure.
# ----------------------------------------------------------------------

# fold constant separating the cohort chain's RNG stream from the
# training chain (both start at PRNGKey(cfg.seed))
_COHORT_FOLD = 0x0C0407


def make_scenario(cfg, fed=None, byz_mask=None, cohort=None):
    """The round body's *traced* per-run operands as a pytree.

    ``sigma``/``scale`` are the attack magnitudes (f32 scalars) and
    ``byz`` the (N,) Byzantine identity mask — everything about a run
    that changes its *numbers* without changing its *trace*.  Baking
    them into the jaxpr (the pre-sweep status quo) meant any sigma
    change recompiled and no two runs could batch; as operands, a run
    is one point on a vmappable scenario axis (fl/sweep.py) and
    magnitude changes are jit cache hits (DESIGN.md §8).

    ``byz_mask`` overrides; else ``fed.byz_mask`` (the federation's
    ground truth — what every solo path uses); else the deterministic
    ``make_byzantine_mask(n_clients, f)`` a ``Federation.create`` with
    this cfg would have produced (what sweep cells use, so a batched
    cell and its solo twin see the same bits).

    With ``cfg.cohort_participation`` set, the scenario additionally
    carries ``"cohort"`` — the precomputed ``(R, N)`` per-round
    participation-mask chain (fl/faults.make_cohort_chain), derived
    deterministically from ``cfg.seed`` on an RNG stream folded away
    from the training chain.  An explicit ``cohort`` overrides and is
    validated host-side (``DegenerateCohortError`` on any zero-client
    round).  As a traced operand the whole chain batches along the
    sweep axis like the byz mask — per-round resampling costs zero
    retraces (DESIGN.md §13)."""
    if byz_mask is None:
        byz_mask = fed.byz_mask if fed is not None else \
            make_byzantine_mask(cfg.n_clients, cfg.f)
    scen = {"sigma": jnp.float32(cfg.attack.sigma),
            "scale": jnp.float32(cfg.attack.scale),
            "byz": jnp.asarray(byz_mask, bool)}
    cp = getattr(cfg, "cohort_participation", None)
    if cohort is not None:
        validate_cohort_chain(cohort, cfg.n_clients, cfg.rounds)
        scen["cohort"] = jnp.asarray(cohort, bool)
    elif cp is not None:
        scen["cohort"] = make_cohort_chain(
            cfg.n_clients, cfg.rounds, cp,
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), _COHORT_FOLD))
    return scen


# Compiles are counted, not inferred: each outer jitted program calls
# its Python body exactly once per cache miss (trace), so a counter
# bumped inside the body is a compile counter.  benchmarks/sweep_bench
# snapshots it to enforce "one compile per structural group"; the
# no-recompile-on-sigma-change regression test reads it too.
TRACE_COUNTS = {"segment": 0, "training": 0, "eval": 0}


def trace_counts():
    """Snapshot of the engine's compile counters (copies, not views)."""
    return dict(TRACE_COUNTS)


class TraceDelta:
    """Live view of compile counts since a :func:`trace_counter` entry.

    ``delta["segment"]`` reads the *current* delta — valid both inside
    and after the ``with`` block; ``snapshot()``/``total()`` summarize."""

    def __init__(self, start):
        self._start = start

    def __getitem__(self, kind):
        return TRACE_COUNTS[kind] - self._start.get(kind, 0)

    def snapshot(self):
        return {k: self[k] for k in TRACE_COUNTS}

    def total(self):
        return sum(self.snapshot().values())


@contextlib.contextmanager
def trace_counter():
    """Scoped compile counting — the supported alternative to poking
    ``TRACE_COUNTS`` directly.

    ``with trace_counter() as tc: ...`` yields a :class:`TraceDelta`
    whose lookups are always relative to the entry snapshot, so nested
    or concurrent-in-sequence counters never clobber each other the way
    ad-hoc reset/re-read of the module dict did.  The global counters
    themselves keep monotonically counting (they are compile *totals*,
    and resetting them under someone else's nose was the bug this API
    exists to prevent)."""
    yield TraceDelta(dict(TRACE_COUNTS))


def _counted(kind, fn):
    """Bump the compile counter for ``kind`` on every trace of ``fn``,
    and — when the flight recorder is on — emit a ``trace`` event with
    the trace wall time and the program's operand/output leaf counts
    (the trace-time proxy for jaxpr size; benches that ``.lower()``
    programs attach exact HLO/memory sizes via their own events)."""
    @functools.wraps(fn)
    def wrapped(*a, **kw):
        TRACE_COUNTS[kind] += 1
        rec = telemetry.get_recorder()
        if not rec.enabled:
            return fn(*a, **kw)
        t0 = rec.now()
        out = fn(*a, **kw)
        rec.event("trace", program=kind, dur=round(rec.now() - t0, 6),
                  in_leaves=len(jax.tree.leaves((a, kw))),
                  out_leaves=len(jax.tree.leaves(out)))
        return out
    return wrapped


# ----------------------------------------------------------------------
# The round body — one definition for every execution mode.
# ----------------------------------------------------------------------

def _apply_update_attacks(U, byz_rows, keys_rows, ka, acfg, scen):
    """Byzantine update corruption on a stack of flattened updates.

    One definition for the dense (N, D) matrix and the streaming
    (chunk, D) blocks — the streaming == dense bitwise contract depends
    on both paths tracing the identical per-row attack graph.
    ``keys_rows`` carries the per-client gaussian subkeys (row-aligned
    with ``U``); every other attack kind ignores the key, so the C-way
    split is skipped and ``ka`` is passed through.  The attack
    magnitudes come from the ``scen`` operands, never from ``acfg``'s
    baked constants — only ``kind`` (graph structure) is static."""
    if acfg.kind not in UPDATE_ATTACKS and acfg.kind != "backdoor":
        return U
    sigma, scale = scen["sigma"], scen["scale"]
    if acfg.kind == "gaussian":          # the only RNG-consuming attack
        U_att = jax.vmap(
            lambda u, k: attack_update(u, acfg.kind, k, acfg,
                                       sigma=sigma, scale=scale))(U, keys_rows)
    else:
        U_att = jax.vmap(
            lambda u: attack_update(u, acfg.kind, ka, acfg,
                                    sigma=sigma, scale=scale))(U)
    # (c, 1) on the classic flat layout — a[:, None] verbatim — and
    # (c, 1, 1) on the blocked (c, ms, L) layout (DESIGN.md §12)
    bsel = byz_rows.reshape(byz_rows.shape + (1,) * (U.ndim - 1))
    return jnp.where(bsel, U_att, U)

def make_round_body(model, fed, cfg, *, client_chunk: Optional[int] = None):
    """Build ``body(params, sub, lr, batch) -> (new_params, logs)``.

    ``sub`` is the round's RNG key, ``lr`` its learning rate, ``batch``
    an optional precomputed ``(xb, yb)`` minibatch stack (shape
    (N, E*m, ...)) — ``None`` samples inside the traced body with the
    same ``kb`` subkey the precomputed path derives, so the two modes
    are bit-identical.  ``scen`` carries the run's traced operands
    (:func:`make_scenario`: attack sigma/scale, the Byzantine mask);
    ``None`` closes over the federation's own values — same bits, but
    baked into the trace (the seed per-round path; every engine path
    threads ``scen`` through as a jit argument instead).

    With ``cfg.streaming`` and an associative aggregator, Steps 2-5 run
    through the streaming subsystem (fl/streaming.py): client updates
    and guiding updates are computed block by block inside one scan and
    folded straight into an O(D) AggState — the (N, D) update/guide
    matrices never materialize, and the result is bit-identical to the
    dense path (DESIGN.md §6).  Non-associative rules fall back to the
    dense path; the reason is logged and exposed as
    ``body.streaming_fallback``.

    With a **lossy** ``cfg.compression`` codec (fl/compression.py) the
    round carry becomes ``(params, resid)``: each selected client
    encodes ``u_i + resid_i`` at the client→server boundary (after the
    Byzantine update attacks — the adversary corrupts the true update,
    then the client's codec compresses whatever it is sending) and keeps
    the quantization error ``resid_i' = v_i − decode(encode(v_i))`` for
    the next round it participates in (error feedback; non-selected
    clients' residuals persist untouched).  The server side only ever
    sees the encoded stream: the streaming fold decodes in-fold (fused
    kernels under ``use_kernel_agg``), the dense registry rules receive
    the decoded values from the shared reference decoder — same bits
    either way (DESIGN.md §10).  Guides are quantize-dequantized with
    the *same* codec inside the enclave (``SecureServer.compute_guides``)
    but carry NO residual — they are recomputed from the root sample
    every round, so there is no error to feed back.  A lossless codec
    (the ``"f32"`` default) skips ALL of this structurally: the body
    keeps the bare-params carry and traces the identical jaxpr as before
    compression existed — bitwise is trivial, not tested-for.
    ``body.lossy``/``body.codec`` expose the resolution.
    """
    E, m = cfg.local_steps, cfg.batch_size
    acfg = cfg.attack
    n_classes = fed.data.n_classes
    entry = get_aggregator(cfg.aggregator)   # fails fast on unknown rules
    C = cfg.n_selected
    codec = get_codec(getattr(cfg, "compression", "f32"))
    lossy = not codec.lossless
    # async rounds (DESIGN.md §13): per-round cohorts / fault injection /
    # staleness buffering.  Everything below is Python-gated on these
    # trace-time constants, so async_mode=False traces the exact PR-9
    # jaxpr — the structural half of the §13 bitwise contract.
    async_mode = bool(getattr(cfg, "async_rounds", False))
    fcfg = getattr(cfg, "fault", None)
    straggler = async_mode and fcfg.kind == "straggler"
    B = int(getattr(cfg, "staleness_buffer", 0)) if async_mode else 0
    cap = int(getattr(cfg, "staleness_cap", 0))
    # stragglers expire wholesale when there is nowhere to land them or
    # the hard cap forbids their age — a static (trace-time) decision
    expire_all = straggler and (B == 0 or (cap > 0 and fcfg.delay > cap))
    # every buffered update lands at age == delay, so the staleness
    # discount is one static factor riding the fold's valid channel
    discount_w = (float(getattr(cfg, "staleness_discount", 1.0))
                  ** fcfg.delay) if async_mode else 1.0
    default_scen = make_scenario(cfg, fed)
    stream_entry, streaming_fallback = None, None
    if getattr(cfg, "streaming", False):
        stream_entry = get_streaming(cfg.aggregator)
        if stream_entry is None:
            streaming_fallback = fallback_reason(cfg.aggregator)
            logger.warning(
                "FLConfig.streaming=True but aggregator %r cannot stream "
                "(%s); falling back to the dense (N, D) aggregation path",
                cfg.aggregator, streaming_fallback)
            telemetry.event("streaming_fallback", aggregator=cfg.aggregator,
                            reason=streaming_fallback)
    if entry.needs_guides:
        # Unseal + cache the guide batches *eagerly*, outside any trace:
        # building the device-side cache under jit/scan tracing would
        # cache tracers (and leak them into later compilations).
        fed.server.guide_batches()

    def grad_fn(params, batch):
        x, y = batch
        return jax.grad(lambda p: model.loss(p, x, y, cfg.l2))(params)

    def client_update(params, xs, ys, lr):
        """xs: (E, m, ...) — E local SGD iterations, fresh batch each.
        The trailing ``astype`` keeps the scan carry dtype-stable for
        low-precision zoo params (bf16 - f32*bf16 promotes); identity —
        and jaxpr-invisible — for the f32 small models."""
        def step(theta, b):
            g = grad_fn(theta, b)
            return jax.tree.map(
                lambda t, gg: (t - lr * gg).astype(t.dtype), theta, g), None
        theta, _ = jax.lax.scan(step, params, (xs, ys))
        return jax.tree.map(lambda a, b: a - b, params, theta)

    def body(carry, sub, lr, batch=None, scen=None):
        astate = None
        if lossy:
            params, resid = carry       # resid: (N, d) f32 EF residuals
        elif async_mode:
            # async and lossy carries are mutually exclusive
            # (FLConfig.__post_init__), so the pair is unambiguous
            params, astate = carry
            resid = None
        else:
            params, resid = carry, None     # bare-params carry, as ever
        if scen is None:
            scen = default_scen
        kb, ka, kr, ks = jax.random.split(sub, 4)
        if batch is None:
            xb, yb = fed.data.minibatch(kb, E * m)
        else:
            xb, yb = batch
        xb = xb.reshape((cfg.n_clients, E, m) + xb.shape[2:])
        yb = yb.reshape((cfg.n_clients, E, m))
        # Step 2 preamble: server samples the participating subset S^i
        sel = jax.random.choice(ks, cfg.n_clients, (C,), replace=False) \
            if C < cfg.n_clients else jnp.arange(cfg.n_clients)
        xb, yb = xb[sel], yb[sel]
        xb, yb = shard_clients(xb), shard_clients(yb)
        byz = scen["byz"][sel]

        live = fault_rows = strag = None
        if async_mode:
            # async mode enforces participation == 1.0, so `sel` is
            # arange(N) and `ks` — the selection subkey — is free: it
            # becomes the fault draw's per-round key.  The 4-way split
            # above stays untouched, which is why a trivial-async run
            # consumes the identical RNG chain as the PR-9 path (the
            # value-bitwise half of the §13 contract).
            if "cohort" in scen:
                m_r = jax.lax.dynamic_index_in_dim(
                    scen["cohort"], astate["r"], axis=0, keepdims=False)
            else:
                m_r = jnp.ones((cfg.n_clients,), bool)
            fault_rows = draw_faults(ks, cfg.n_clients, fcfg)
            if fcfg.kind in ("dropout", "straggler"):
                # the update never arrives this round: drop the client
                # from the live set (zero fold weight via the `live`
                # context channel)
                live = m_r & ~fault_rows
            else:
                live = m_r
            if straggler:
                strag = m_r & fault_rows

        # ---- data-level attacks ----
        if acfg.kind == "label_flip":
            yb = jnp.where(byz[:, None, None], flip_labels(yb, n_classes), yb)
        elif acfg.kind == "backdoor":
            def poison(xc, yc):
                xf = xc.reshape((E * m,) + xc.shape[2:])
                yf = yc.reshape(E * m)
                xp, yp = poison_backdoor(xf, yf, acfg)
                return xp.reshape(xc.shape), yp.reshape(yc.shape)
            xp, yp = jax.vmap(poison)(xb, yb)
            bsel = byz.reshape((-1,) + (1,) * (xb.ndim - 1))
            xb = jnp.where(bsel, xp, xb)
            yb = jnp.where(byz[:, None, None], yp, yb)

        logs = {"byz": byz, "sel": sel}
        root = None
        if entry.needs_root:
            root_tree = fed.server.compute_root_update(
                params, grad_fn, lr, E, fed.root_x, fed.root_y)
            if model_shard_count() > 1:
                # blocked (ms, L) layout, same column offsets as the
                # client update blocks — the fltrust dot aligns
                # element-for-element (DESIGN.md §12)
                root = ravel_sharded(root_tree)
            else:
                r, _ = agg.flatten_updates(
                    jax.tree.map(lambda a: a[None], root_tree))
                root = shard_flat(r[0])

        if stream_entry is not None:
            # ---- Steps 2-5, streaming: fold blocks into an AggState ----
            # Only O(C) per-client scalars (selection ids, Byzantine bits,
            # attack keys) and the O(C·batch) minibatch stack persist
            # across blocks; updates and guides live one chunk at a time.
            ctx = AggregationContext(
                key=kr, f=cfg.f, dfl=cfg.dfl, byz_mask=byz, guides=None,
                root_update=root, resample_s=cfg.resample_s,
                use_kernel_stats=cfg.use_kernel_stats,
                use_kernel_agg=cfg.use_kernel_agg,
                stream_shards=getattr(cfg, "stream_shards", None),
                stream_pods=getattr(cfg, "pods", None),
                codec=codec if lossy else None)
            rule = fed.server.streaming_aggregator(cfg.aggregator, ctx)
            keys = jax.random.split(ka, C) if acfg.kind == "gaussian" else None

            def block_fn(blk, valid):
                live_b = fault_b = None
                if lossy:
                    xs, ys, byz_b, sel_b, keys_b, resid_b = blk
                elif async_mode:
                    xs, ys, byz_b, sel_b, keys_b, live_b, fault_b = blk
                else:
                    xs, ys, byz_b, sel_b, keys_b = blk
                upd = jax.vmap(
                    lambda x, y: client_update(params, x, y, lr))(xs, ys)
                if model_shard_count() > 1:
                    # blocked (chunk, ms, L) build: the concat runs
                    # along the unsharded column dim, so no unsharded
                    # (chunk, D) fp32 temp ever materializes — the
                    # envelope difference at zoo scale (DESIGN.md §12)
                    U_blk, _ = flatten_updates_sharded(upd)
                else:
                    U_blk, _ = agg.flatten_updates(upd)
                U_blk = _apply_update_attacks(U_blk, byz_b, keys_b, ka, acfg,
                                              scen)
                if async_mode and fcfg.kind == "intermittent":
                    # device malfunction at the client boundary, AFTER
                    # the adversarial attack: the corruption hits
                    # whatever bits the client actually transmits
                    U_blk = corrupt_updates(U_blk, fault_b, fcfg)
                # same client x model sharding contract as the dense
                # branch, per block: client dim over the data axes, flat
                # D over the model axis (each no-op without a mesh /
                # when its dim won't tile — DESIGN.md §12)
                U_blk = shard_updates(U_blk)
                ctx_blk = {"byz": byz_b}
                if async_mode:
                    # cohort membership minus this round's dropouts —
                    # the fold's second multiplicative weight channel
                    ctx_blk["live"] = live_b
                if entry.needs_guides:
                    # flat=True: the enclave ravels (and quantizes) each
                    # guide inside its chunked map, so the block's guide
                    # working set is O(chunk x model) — the stacked guide
                    # pytree never coexists with its flat copy
                    ctx_blk["guide"] = fed.server.compute_guides(
                        params, grad_fn, lr, E, select=sel_b,
                        codec=codec if lossy else None, flat=True)
                if lossy:
                    # client→server boundary: encode v = u + resid, keep
                    # the new quantization error; ONLY the encoded pytree
                    # enters the fold (the rule decodes it in-fold).  On
                    # the blocked layout the residual plane stays (N, d)
                    # flat in blocked element order (d == ms·L — lossy +
                    # model sharding requires pad-free leaves, enforced
                    # by FLConfig.validate_model_sharding)
                    if U_blk.ndim == 3:
                        resid_b = resid_b.reshape(U_blk.shape)
                    enc, _, new_resid_b = encode_with_feedback(
                        codec, U_blk, resid_b)
                    enc = jax.tree.map(shard_updates, enc)
                    if new_resid_b.ndim == 3:
                        new_resid_b = new_resid_b.reshape(
                            new_resid_b.shape[0], -1)
                    return enc, ctx_blk, new_resid_b
                return U_blk, ctx_blk

            d = sum(p.size for p in jax.tree.leaves(params))
            # flat output unused -> DCE'd; only the unravel closure (and
            # the blocked layout's static (ms, L) state shape) is kept
            if model_shard_count() > 1:
                f0, unravel = flatten_updates_sharded(
                    jax.tree.map(lambda p: p[None], params))
                d = f0.shape[1:]
            else:
                _, unravel = agg.flatten_updates(
                    jax.tree.map(lambda p: p[None], params))
            # ---- bounded-staleness landing (DESIGN.md §13) ----------
            # Buffered straggler updates whose TTL hits zero this round
            # fold through the SAME AggState monoid as the live cohort,
            # with guides recomputed at the LANDING round's params — so
            # Eq. 6 filters stale-and-diverged updates per client.  The
            # partial state merges into the block sweep's result just
            # before finalize (stream_aggregate's extra_state hook).
            extra_state = None
            stale_logs = None
            landed = ttl1 = None
            stale_folded = jnp.zeros((), jnp.int32) if async_mode else None
            land_cid = None
            if B > 0:
                ttl1 = astate["ttl"] - 1
                landed = astate["on"] & (ttl1 <= 0)
                land_cid = astate["cid"]
                land_ctx = {"byz": scen["byz"][land_cid],
                            # the staleness discount rides the exact 0/1
                            # valid channel as a static factor — no rule
                            # changes, dead slots get weight 0.0
                            "valid": landed.astype(jnp.float32)
                            * jnp.float32(discount_w)}
                if entry.needs_guides:
                    land_ctx["guide"] = fed.server.compute_guides(
                        params, grad_fn, lr, E, select=astate["cid"],
                        flat=True)
                extra_state, stale_logs = jax.lax.scan(
                    lambda st, uc: rule.update(st, uc[0], uc[1]),
                    rule.init(d), (astate["u"], land_ctx), unroll=1)
                stale_folded = jnp.sum(landed.astype(jnp.int32))

            # pods > 1 runs the two-tier fold: block_fn — and with it the
            # enclave's guide computation — executes inside the pod-local
            # scan, so guides and updates are chunked *per pod* and the
            # enclave memory model holds per-pod (DESIGN.md §9)
            if lossy:
                delta, agg_logs, client_logs, new_resid = stream_aggregate(
                    rule, block_fn,
                    (xb, yb, byz, sel, keys, resid[sel]), client_chunk,
                    d=d, prefer_block=cfg.use_kernel_agg,
                    shards=ctx.stream_shards, pods=ctx.stream_pods,
                    block_extra=True)
                resid = resid.at[sel].set(new_resid)
            else:
                args = (xb, yb, byz, sel, keys)
                if async_mode:
                    args = args + (live, fault_rows)
                delta, agg_logs, client_logs = stream_aggregate(
                    rule, block_fn, args, client_chunk,
                    d=d, prefer_block=cfg.use_kernel_agg,
                    shards=ctx.stream_shards, pods=ctx.stream_pods,
                    extra_state=extra_state)
            logs.update(client_logs)
            logs.update(agg_logs)

            # ---- buffer refill: this round's stragglers -------------
            if async_mode:
                N = cfg.n_clients
                stale_buffered = jnp.zeros((), jnp.int32)
                stale_expired = jnp.zeros((), jnp.int32)
                new_astate = {"r": astate["r"] + 1}
                if expire_all:
                    stale_expired = jnp.sum(strag.astype(jnp.int32))
                if B > 0:
                    on2 = astate["on"] & ~landed
                    ttl_keep = jnp.maximum(ttl1, 0)
                    if straggler and not expire_all:
                        # rank-assign stragglers (in client order) to
                        # free slots; the overflow expires.  O(B·model)
                        # recompute keeps the slab O(buffer·D): slots
                        # store only the FLAT update, rebuilt from the
                        # round's own batch at the round's own params.
                        ns = jnp.sum(strag.astype(jnp.int32))
                        order = jnp.argsort(
                            jnp.where(strag, jnp.arange(N),
                                      N + jnp.arange(N)))
                        # per-slot rank among FREE slots: slot with free
                        # rank j takes the j-th straggler in client
                        # order; ranks >= ns (or occupied slots) don't
                        free = ~on2
                        free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1
                        take = free & (free_rank < ns)
                        src = order[jnp.clip(free_rank, 0, N - 1)]
                        upd_s = jax.vmap(
                            lambda i: client_update(params, xb[i], yb[i],
                                                    lr))(src)
                        if model_shard_count() > 1:
                            U_s, _ = flatten_updates_sharded(upd_s)
                        else:
                            U_s, _ = agg.flatten_updates(upd_s)
                        keys_s = keys[src] if keys is not None else None
                        U_s = _apply_update_attacks(
                            U_s, scen["byz"][src], keys_s, ka, acfg, scen)
                        tsel = take.reshape(take.shape
                                            + (1,) * (U_s.ndim - 1))
                        new_astate.update(
                            u=jnp.where(tsel, U_s.astype(jnp.float32),
                                        astate["u"]),
                            cid=jnp.where(take, src, astate["cid"]),
                            ttl=jnp.where(take, jnp.int32(fcfg.delay),
                                          ttl_keep),
                            on=on2 | take)
                        stale_buffered = jnp.sum(take.astype(jnp.int32))
                        stale_expired = ns - stale_buffered
                    else:
                        new_astate.update(u=astate["u"],
                                          cid=astate["cid"],
                                          ttl=ttl_keep, on=on2)
                astate = new_astate

                # ---- async accounting: per-client rows + counts -----
                # landed slot rows join the per-client log plane so the
                # tag/TPR/FPR accounting covers them at their landing
                # round; `cand` marks which rows actually participated
                cand = live
                if B > 0 and stale_logs is not None:
                    for k in list(logs):
                        if k in stale_logs:
                            logs[k] = jnp.concatenate(
                                [logs[k], stale_logs[k]])
                    logs["byz"] = jnp.concatenate(
                        [byz, scen["byz"][land_cid]])
                    logs["sel"] = jnp.concatenate([sel, land_cid])
                    cand = jnp.concatenate([live, landed])
                logs["cand"] = cand
                logs["cohort"] = jnp.sum(live.astype(jnp.int32))
                logs["stale_buffered"] = stale_buffered
                logs["stale_folded"] = stale_folded
                logs["stale_expired"] = stale_expired
        else:
            # ---- Step 2: client local training (chunked federation) ----
            updates = chunked_vmap(
                lambda x, y: client_update(params, x, y, lr), (xb, yb),
                client_chunk)
            U, unravel = agg.flatten_updates(updates)
            U = shard_updates(U)

            # ---- update-level attacks ----
            if acfg.kind in UPDATE_ATTACKS or acfg.kind == "backdoor":
                keys = jax.random.split(ka, C) \
                    if acfg.kind == "gaussian" else None
                U = _apply_update_attacks(U, byz, keys, ka, acfg, scen)
                U = shard_updates(U)

            if lossy:
                # client→server boundary: the registry rules receive the
                # *decoded* updates — the exact bits the shared reference
                # decoder recovers from the wire payload, so dense and
                # streaming agree on what the server saw (DESIGN.md §10)
                _, U, new_resid = encode_with_feedback(codec, U, resid[sel])
                resid = resid.at[sel].set(new_resid)
                U = shard_updates(U)

            # ---- Steps 3-5: SecureServer (enclave guides -> registry) ----
            G = None
            if entry.needs_guides:
                G = fed.server.compute_guides(
                    params, grad_fn, lr, E, select=sel,
                    client_chunk=client_chunk,
                    codec=codec if lossy else None, flat=True)
            ctx = AggregationContext(
                key=kr, f=cfg.f, dfl=cfg.dfl, byz_mask=byz, guides=G,
                root_update=root, resample_s=cfg.resample_s,
                use_kernel_stats=cfg.use_kernel_stats,
                use_kernel_agg=cfg.use_kernel_agg,
                codec=None)   # dense rules already hold decoded values
            delta, agg_logs = fed.server.aggregate(cfg.aggregator, U, ctx)
            logs.update(agg_logs)

        # the per-leaf constraints pin the updated parameters back to the
        # MODEL_AXIS partition-table layout, so the scan carry keeps its
        # tensor-parallel placement round over round (no-op off a
        # model-sharded mesh — the pre-zoo jaxpr is unchanged)
        new_params = shard_params(jax.tree.map(
            lambda p, d: (p - d).astype(p.dtype), params, unravel(delta)))
        if lossy:
            return (new_params, resid), logs
        if async_mode:
            return (new_params, astate), logs
        return new_params, logs

    body.streaming = stream_entry is not None
    body.streaming_fallback = streaming_fallback
    body.lossy = lossy
    body.codec = codec
    body.async_mode = async_mode
    return body


# each round's batch subkey, exactly as the body derives it:
# kb = split(sub, 4)[0] (jitted once; eager vmap would retrace per call)
_batch_keys = jax.jit(jax.vmap(lambda s: jax.random.split(s, 4)[0]))


# ----------------------------------------------------------------------
# RoundEngine
# ----------------------------------------------------------------------

class RoundEngine:
    """Compile federated rounds into donated scans — per segment or for
    the whole training run.

    ``run_segment(params, key, lrs)`` executes ``len(lrs)`` rounds in a
    single dispatch, advancing the caller's RNG chain exactly as the
    legacy per-round loop would (``key, sub = split(key)`` per round),
    and returns ``(params, key, last_logs)`` where ``last_logs`` is the
    final round's log dict — the one the eval point reads.

    ``run_training(params, key, lrs)`` goes one level further: the whole
    multi-segment run compiles into **one outer ``lax.scan`` over eval
    segments** whose body is the segment scan followed by the device
    eval tail (fl/metrics.make_eval_fn) — main-task/backdoor accuracy
    and detection TPR/FPR accumulate into a per-eval-point metric buffer
    on device, and the host syncs exactly once when the caller fetches
    it (DESIGN.md §7).  ``run_training_sweep`` vmaps that program over
    a stacked scenario axis — a whole structural group of runs in one
    compile and one dispatch (fl/sweep.py, DESIGN.md §8).

    ``batch_mode``:
      * ``"inline"``  — minibatches are sampled inside the traced body
        (memory-light; the default off-mesh);
      * ``"segment"`` — the data pipeline serves a per-segment
        minibatch stack (data/pipeline.segment_minibatches) placed with
        client-axis NamedShardings (the default when a mesh is active,
        so batch data lives distributed from the start).
    Both derive batches from the same ``kb`` subkeys — bit-identical.
    ``run_segment`` honors the mode; ``run_training`` always samples
    inline (a whole run's batch stacks would scale the batch working
    set by the segment count).

    ``donate``: tri-state scan-carry donation knob.  ``None`` resolves
    to ``cfg.donate``, and a ``None`` there means *auto* — donate
    wherever the backend supports it (XLA:CPU does not, so auto skips
    the warning-spamming request there).  ``True``/``False`` force the
    request on or off regardless of backend, which is what lets
    benchmarks/dispatch_bench measure the donation working-set delta.
    """

    def __init__(self, model, fed, cfg, *, eval_every: Optional[int] = None,
                 client_chunk: Optional[int] = None,
                 batch_mode: Optional[str] = None, mesh=None,
                 donate: Optional[bool] = None):
        self.model, self.fed, self.cfg = model, fed, cfg
        self.eval_every = eval_every if eval_every is not None \
            else cfg.eval_every
        self.client_chunk = client_chunk if client_chunk is not None \
            else getattr(cfg, "client_chunk", None)
        self.mesh = mesh if mesh is not None else get_mesh()
        if batch_mode is None:
            batch_mode = "segment" if self.mesh is not None else "inline"
        if batch_mode not in ("inline", "segment"):
            raise ValueError(f"unknown batch_mode {batch_mode!r}")
        self.batch_mode = batch_mode
        # tensor parallelism: >1 iff the mesh carries a non-trivial
        # ``model`` axis.  The knob-compatibility check needs the flat
        # model dim, which only exists once params are seen — deferred
        # to the first run_* call (cached; see _check_model_sharding)
        self.model_shards = model_shard_count(self.mesh)
        self._model_sharding_checked = False
        self._body = make_round_body(model, fed, cfg,
                                     client_chunk=self.client_chunk)
        # observability: did the body take the streaming path, and if not
        # (streaming requested but rule not associative), why not
        self.streaming = self._body.streaming
        self.streaming_fallback = self._body.streaming_fallback
        # lossy compression threads an (N, d) error-feedback residual
        # through every carry: the engine's params slot becomes
        # (params, resid) and callers go through init_carry/carry_params
        self.lossy = self._body.lossy
        self.codec = self._body.codec
        # async rounds wrap the carry as (params, async state): a round
        # counter indexing the cohort chain plus, with staleness_buffer
        # > 0, the O(buffer·D) pending slab (DESIGN.md §13)
        self.async_mode = self._body.async_mode
        # on-device round telemetry (DESIGN.md §11): a per-round block of
        # device scalars accumulated inside the scan and drained at the
        # caller's one host sync — never a new round-trip.  Off by
        # default; off means the empty pytree, i.e. the exact
        # pre-telemetry program.
        self.telemetry = bool(getattr(cfg, "telemetry", False))
        self._tel_fn = telemetry.make_round_telemetry_fn(cfg) \
            if self.telemetry else None
        if donate is None:
            donate = getattr(cfg, "donate", None)
        if donate is None:                   # auto: backend support only
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        self.default_scenario = make_scenario(cfg, fed)
        jit_kwargs = {"static_argnums": (3,)}
        donate_kw = {"donate_argnums": (0,)} if self.donate else {}
        self._segment = jax.jit(_counted("segment", self._segment_fn),
                                **jit_kwargs, **donate_kw)
        self._training = jax.jit(_counted("training", self._training_fn),
                                 **donate_kw)
        # the sweep twins: one extra leading scenario axis on every
        # operand, one compile + one dispatch for a whole structural
        # group of runs (fl/sweep.py, DESIGN.md §8).  Wrapping the same
        # Python bodies keeps the compile counters shared: a sweep
        # group's compile counts exactly like a solo run's.
        self._training_sweep = jax.jit(
            jax.vmap(_counted("training", self._training_fn)), **donate_kw)
        self._segment_sweep = jax.jit(
            jax.vmap(_counted("segment", self._segment_sweep_fn)),
            **donate_kw)
        self._eval_fn = make_eval_fn(model, fed, cfg)
        self._eval_jit = jax.jit(_counted("eval", self._eval_fn))
        self._eval_sweep = jax.jit(jax.vmap(_counted("eval", self._eval_fn)))

    def eval_metrics(self, params, logs):
        """Device metric dict for one eval point — the jitted form of the
        same eval the one-dispatch scan tail traces (bitwise equal)."""
        return self._eval_jit(params, logs)

    # --- the error-feedback carry (lossy compression) -----------------

    def _flat_shape(self, params):
        """The flat-update shape one client produces under the active
        layout: ``(d,)`` classic, blocked ``(ms, L)`` model-sharded.
        Abstract (eval_shape) — no device allocation."""
        if self.model_shards > 1:
            f0 = jax.eval_shape(
                lambda p: flatten_updates_sharded(
                    jax.tree.map(lambda q: q[None], p))[0], params)
            return tuple(f0.shape[1:])
        return (sum(p.size for p in jax.tree.leaves(params)),)

    def init_carry(self, params):
        """The round-scan carry for ``params``: bare params for lossless
        codecs (every pre-compression jaxpr unchanged), ``(params,
        zeros(N, d))`` — fresh residuals — under lossy compression,
        ``(params, async state)`` under async rounds (the two wrapped
        forms are mutually exclusive — FLConfig.__post_init__)."""
        if self.lossy:
            d = sum(p.size for p in jax.tree.leaves(params))
            return params, jnp.zeros((self.cfg.n_clients, d), jnp.float32)
        if self.async_mode:
            return params, init_async_state(self.cfg,
                                            self._flat_shape(params))
        return params

    def carry_params(self, carry):
        """The params inside a carry (identity for lossless codecs)."""
        return carry[0] if (self.lossy or self.async_mode) else carry

    def _ensure_carry(self, carry):
        """Accept bare params where a carry is expected — existing call
        sites that never heard of residuals or async state keep working
        (their runs start from zero residual / round zero, which is what
        a fresh run means)."""
        if self.lossy:
            if (isinstance(carry, tuple) and len(carry) == 2
                    and getattr(carry[1], "ndim", None) == 2):
                return carry
            return self.init_carry(carry)
        if self.async_mode:
            if (isinstance(carry, tuple) and len(carry) == 2
                    and isinstance(carry[1], dict) and "r" in carry[1]):
                return carry
            return self.init_carry(carry)
        return carry

    def _prepare_carry(self, carry):
        """Model-sharded runs only: validate the cfg against the actual
        flat dim (named errors, once) and eagerly place the params with
        the MODEL_AXIS partition table — the one host->device scatter
        before the compiled segments take over.  Identity off a
        model-sharded mesh."""
        carry = self._ensure_carry(carry)
        if self.model_shards <= 1:
            return carry
        params = self.carry_params(carry)
        if not self._model_sharding_checked:
            leaves = jax.tree.leaves(params)
            self.cfg.validate_model_sharding(
                sum(p.size for p in leaves), self.model_shards,
                streaming_fallback=self.streaming_fallback,
                leaf_sizes=tuple(p.size for p in leaves))
            self._model_sharding_checked = True
        params = place_params(params, self.mesh)
        if self.lossy or self.async_mode:
            return (params, carry[1])
        return params

    def _scan_rounds(self, params, subs, lrs, with_batches, batches, scen):
        """One segment: scan ``len(lrs)`` round bodies, return the final
        round's logs (the only logs an eval point reads) plus the
        per-round telemetry block (``{}`` with telemetry off — the extra
        ys slot is structurally empty, so the pre-telemetry jaxpr is
        unchanged).  ``scen`` is scan-invariant — the same operand every
        round reads."""
        def step(p, xs):
            if with_batches:
                sub, lr, batch = xs
            else:
                (sub, lr), batch = xs, None
            p, logs = self._body(p, sub, lr, batch, scen)
            tel = self._tel_fn(logs) if self._tel_fn is not None else {}
            return p, (logs, tel)
        xs = (subs, lrs, batches) if with_batches else (subs, lrs)
        params, (logs, tel) = jax.lax.scan(step, params, xs)
        # only the final round's logs leave the device: that is what the
        # eval point reads, and slicing inside the compiled segment keeps
        # the host side to one dispatch (T eager slices would dwarf the
        # scan itself on CPU).  The telemetry block is the exception —
        # per-round device scalars are exactly what it exists to keep —
        # so its (T,)-stacked leaves ride the same dispatch.
        return params, jax.tree.map(lambda x: x[-1], logs), tel

    def _segment_fn(self, params, subs, lrs, with_batches, batches, scen):
        return self._scan_rounds(params, subs, lrs, with_batches, batches,
                                 scen)

    def _segment_sweep_fn(self, params, subs, lrs, scen):
        """The vmappable segment program (no precomputed batch stacks —
        sweeps always sample in-body, like ``run_training``)."""
        return self._scan_rounds(params, subs, lrs, False, None, scen)

    def _training_fn(self, params, subs, lrs, scen):
        """The one-dispatch program: outer scan over (S, T)-shaped
        segment stacks; each step runs the segment scan then the device
        eval tail, so the stacked ys are the (num_evals, k) metric
        buffer — plus the (S, T)-stacked per-round telemetry block when
        telemetry is on — and nothing but the final carry + buffers
        leaves XLA.  Minibatches are always sampled inside the traced
        body (bit-identical to the per-segment batch stacks — same
        ``kb`` subkeys): a whole-run (S, T, N, m, ...) stack would scale
        the batch working set by S, the opposite of the constant-memory
        story the engine exists for."""
        def seg(p, xs):
            sub, lr = xs
            p, logs, tel = self._scan_rounds(p, sub, lr, False, None, scen)
            return p, (self._eval_fn(self.carry_params(p), logs), tel)
        return jax.lax.scan(seg, params, (subs, lrs))

    @staticmethod
    @functools.partial(jax.jit, static_argnums=(1,))
    def _segment_keys(key, n_rounds: int):
        """The legacy loop's exact per-round subkey chain (``key, sub =
        split(key)`` n times), staged as one scan so precomputing a
        segment's keys costs one dispatch, not n."""
        def step(k, _):
            k, sub = jax.random.split(k)
            return k, sub
        return jax.lax.scan(step, key, None, length=n_rounds)

    def run_segment(self, params, key, lrs, scen=None):
        """Run ``len(lrs)`` rounds; returns (params, advanced key, last logs).

        ``scen`` (default: the engine's own federation/config values)
        carries the traced per-run operands — see :func:`make_scenario`;
        passing a different scenario reuses the compiled program.

        Under lossy compression the params slot is the ``(params,
        resid)`` carry — bare params are accepted (zero residual) and
        the advanced *carry* is returned, so chained ``run_segment``
        calls (the host-eval loop) keep the error feedback flowing;
        ``carry_params`` unwraps.  Lossless codecs: params in, params
        out, exactly as before."""
        if scen is None:
            scen = self.default_scenario
        lrs = jnp.asarray(lrs, jnp.float32)
        n = int(lrs.shape[0])
        key, subs = self._segment_keys(key, n)
        carry = self._prepare_carry(params)
        with use_mesh(self.mesh):
            if self.batch_mode == "segment":
                kbs = _batch_keys(subs)
                batches = self.fed.data.segment_minibatches(
                    kbs, self.cfg.local_steps * self.cfg.batch_size)
                carry, logs, _ = self._segment(carry, subs, lrs, True,
                                               batches, scen)
            else:
                carry, logs, _ = self._segment(carry, subs, lrs, False, None,
                                               scen)
        return carry, key, logs

    def run_training(self, params, key, lrs, scen=None):
        """Run ``len(lrs)`` rounds as one device-resident program.

        Segments of ``eval_every`` rounds compile into a single outer
        scan with the eval tail inside (one dispatch, zero host syncs —
        the caller fetches the returned metric buffer whenever it wants
        the one sync).  The RNG chain, segmentation, and eval points are
        exactly ``run_segment`` in a loop: a non-divisible ``rounds``
        leaves a shorter final segment, which runs as one extra dispatch
        with its eval row concatenated on device.  Minibatches are
        sampled inside the scan regardless of ``batch_mode`` — the
        modes are bit-identical, and staging a whole run's batch stacks
        would multiply the batch working set by the segment count.

        Returns ``(params, advanced key, metrics, eval_rounds)`` where
        ``metrics`` is a dict of device arrays with leading dim = number
        of eval points and ``eval_rounds`` the (host) round index each
        metric row was evaluated at — the one definition of the eval
        points, so callers cannot drift from the segmentation that
        actually ran.
        """
        if scen is None:
            scen = self.default_scenario
        lrs = jnp.asarray(lrs, jnp.float32)
        R = int(lrs.shape[0])
        T = self.eval_every
        S, rem = divmod(R, T)
        key, subs = self._segment_keys(key, R)
        carry = self._prepare_carry(params)
        with use_mesh(self.mesh):
            metrics, tel = None, None
            if S:
                # (R, *key) -> (S, T, *key): agnostic to the PRNG key
                # representation (raw uint32 pairs today, typed keys
                # tomorrow)
                carry, (metrics, tel) = self._training(
                    carry,
                    subs[:S * T].reshape((S, T) + subs.shape[1:]),
                    lrs[:S * T].reshape(S, T), scen)
                # (S, T, ...) segment-stacked telemetry -> (R', ...)
                tel = jax.tree.map(
                    lambda x: x.reshape((S * T,) + x.shape[2:]), tel)
            if rem:
                # the carry — residual included — flows into the tail
                # segment: error feedback does not reset at eval points
                carry, logs, tel_tail = self._segment(
                    carry, subs[S * T:], lrs[S * T:], False, None, scen)
                row = jax.tree.map(
                    lambda x: jnp.asarray(x)[None],
                    self._eval_jit(self.carry_params(carry), logs))
                metrics = row if metrics is None else jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b]), metrics, row)
                tel = tel_tail if tel is None else jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b]), tel, tel_tail)
            if self.telemetry and tel:
                # reserved key: drained (popped) by the caller right
                # after its one host sync — never part of the history,
                # so telemetry-on histories stay bitwise-identical to
                # telemetry-off ones
                metrics = dict(metrics)
                metrics["_tel"] = tel
        eval_rounds = [T * (s + 1) for s in range(S)] + ([R] if rem else [])
        return self.carry_params(carry), key, metrics, eval_rounds

    # --- the batched scenario axis (fl/sweep.py) ----------------------

    @staticmethod
    @functools.partial(jax.jit, static_argnums=(1,))
    def _sweep_segment_keys(keys, n_rounds: int):
        """Per-cell RNG chains: ``_segment_keys`` vmapped over a (G, ...)
        stack of run keys — each cell advances exactly the chain its
        solo run would."""
        return jax.vmap(
            lambda k: RoundEngine._segment_keys(k, n_rounds))(keys)

    def run_training_sweep(self, params, keys, lrs, scen):
        """Run a whole *structural group* of training runs in one
        compile and (per eval-divisible round count) one dispatch.

        Every operand carries a leading scenario axis G — ``params`` a
        stacked init pytree, ``keys`` (G, *key) run keys, ``lrs``
        (G, R) per-cell learning-rate vectors, ``scen`` a stacked
        :func:`make_scenario` pytree — and the one-dispatch program of
        :meth:`run_training` is vmapped over it, so the G runs execute
        as one batched device program: same segmentation, same RNG
        chains, same eval points, cell g bitwise-equal to the solo run
        (DESIGN.md §8).  With a mesh active the scenario axis is placed
        over the data axes (``sharding.sweep_put``), running cells in
        parallel across devices.  Returns ``(params, keys, metrics,
        eval_rounds)`` with metrics leaves shaped (G, num_evals, ...).
        """
        lrs = jnp.asarray(lrs, jnp.float32)
        G, R = int(lrs.shape[0]), int(lrs.shape[1])
        T = self.eval_every
        S, rem = divmod(R, T)
        keys, subs = self._sweep_segment_keys(keys, R)
        carry = params
        if self.lossy:
            # stacked carry: one (N, d) residual plane per sweep cell
            d = sum(l.size // l.shape[0] for l in jax.tree.leaves(params))
            carry = (params,
                     jnp.zeros((G, self.cfg.n_clients, d), jnp.float32))
        elif self.async_mode:
            # stacked async state: one round counter (+ pending slab)
            # per sweep cell — all zeros, like each cell's solo init
            ast = init_async_state(
                self.cfg,
                self._flat_shape(jax.tree.map(lambda l: l[0], params)))
            carry = (params, jax.tree.map(
                lambda x: jnp.zeros((G,) + x.shape, x.dtype), ast))
        with use_mesh(self.mesh):
            carry, lrs, scen, subs = sweep_put((carry, lrs, scen, subs))
            metrics, tel = None, None
            if S:
                carry, (metrics, tel) = self._training_sweep(
                    carry,
                    subs[:, :S * T].reshape((G, S, T) + subs.shape[2:]),
                    lrs[:, :S * T].reshape(G, S, T), scen)
                # (G, S, T, ...) -> (G, R', ...): per-cell round axis
                tel = jax.tree.map(
                    lambda x: x.reshape((G, S * T) + x.shape[3:]), tel)
            if rem:
                carry, logs, tel_tail = self._segment_sweep(
                    carry, subs[:, S * T:], lrs[:, S * T:], scen)
                row = jax.tree.map(
                    lambda x: jnp.asarray(x)[:, None],
                    self._eval_sweep(self.carry_params(carry), logs))
                metrics = row if metrics is None else jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], axis=1),
                    metrics, row)
                tel = tel_tail if tel is None else jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], axis=1),
                    tel, tel_tail)
            if self.telemetry and tel:
                metrics = dict(metrics)
                metrics["_tel"] = tel   # (G, R, ...) — popped per cell
        eval_rounds = [T * (s + 1) for s in range(S)] + ([R] if rem else [])
        return self.carry_params(carry), keys, metrics, eval_rounds
