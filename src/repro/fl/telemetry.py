"""Flight recorder — structured telemetry, round tracing, enclave audit
(DESIGN.md §11).

The compiled engine (§5-§10) is deliberately a black box between
``run_training`` and the single ``host_sync``: nothing observable leaves
the device mid-run.  That is the right execution model and the wrong
observability model — the paper's core claim (the per-client C1/C2
criterion tags exactly the faulty clients) was only visible by digging
through raw history arrays, and production TEE-FL deployments (SecFL,
Separation-of-Powers in PAPERS.md) treat an inspectable trail as a
first-class requirement.  This module is that trail, in three parts:

  * **Spans + events** — a process-wide :class:`Recorder`.
    ``span("compile")``/``event(...)`` emit structured records
    (monotonic wall time, kind, static metadata such as N/D/chunk/pods/
    codec).  Recording is OFF by default and every instrumentation site
    is a cheap ``enabled()`` check, so the disabled recorder costs one
    attribute read — the instrumented seams (engine trace counters,
    ``simulator.host_sync``, sweep group compiles, streaming fallbacks)
    stay on the exact pre-telemetry code paths.
  * **On-device round telemetry** — :func:`make_round_telemetry_fn`
    builds the per-round telemetry block the engine accumulates
    *inside* the scan (C1/C2 pass counts, tagged-client popcount,
    update/guide norm summaries): a handful of device scalars per round
    riding the existing metric buffer, drained at the existing single
    ``host_sync``.  Zero new host round-trips — CI-gated by the
    dispatch bench's sync counter.
  * **Enclave audit log** — :class:`AuditLog`, an append-only
    hash-chained record (each entry commits to the previous digest) the
    ``SecureServer`` writes attestation, seal/unseal, guide-cache
    rebuilds and per-round tag decisions into.  ``verify_entries``
    recomputes the chain; ``launch/observe.py`` renders a recorded run
    (span waterfall, round tag timeline, comm columns) from the JSONL
    export and verifies the chain end-to-end.

**What is deliberately NOT recorded** (DESIGN.md §11): raw client
updates, guide samples, or anything derived from unsealed enclave data
beyond aggregate counts and norm summaries — the audit trail must be
publishable without weakening the trust boundary it documents.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import time
from typing import Any, Dict, List, Optional

import jax.numpy as jnp

SCHEMA_VERSION = 1

# The hash chain's genesis digest: the first entry commits to this.
GENESIS = "0" * 64


# ----------------------------------------------------------------------
# Recorder — spans + events
# ----------------------------------------------------------------------

class Recorder:
    """Process-wide flight recorder: structured spans and events.

    Records are plain dicts (JSON-ready).  An **event** is a point in
    time: ``{"type": "event", "kind", "t", **meta}``.  A **span** is an
    interval: ``{"type": "span", "name", "t0", "t1", "dur", "depth",
    **meta}`` — ``depth`` is the nesting level at entry, which is all
    ``launch/observe.py`` needs to indent the waterfall.  Times are
    seconds since :meth:`start` (monotonic clock); the wall-clock epoch
    of ``t=0`` is kept once in :attr:`wall0` so exports stay
    correlatable across processes without every record paying a
    wall-clock read."""

    def __init__(self):
        self.enabled = False
        self.records: List[dict] = []
        self.wall0 = 0.0
        self._t0 = 0.0
        self._depth = 0

    # --- lifecycle ----------------------------------------------------
    def start(self) -> "Recorder":
        self.records = []
        self.enabled = True
        self.wall0 = time.time()
        self._t0 = time.monotonic()
        self._depth = 0
        return self

    def stop(self) -> None:
        self.enabled = False

    def now(self) -> float:
        return time.monotonic() - self._t0

    # --- emission -----------------------------------------------------
    def event(self, kind: str, **meta) -> None:
        if not self.enabled:
            return
        self.records.append({"type": "event", "kind": kind,
                             "t": round(self.now(), 6), **meta})

    @contextlib.contextmanager
    def span(self, name: str, **meta):
        if not self.enabled:
            yield
            return
        rec = {"type": "span", "name": name, "t0": round(self.now(), 6),
               "depth": self._depth, **meta}
        self._depth += 1
        try:
            yield
        finally:
            self._depth -= 1
            rec["t1"] = round(self.now(), 6)
            rec["dur"] = round(rec["t1"] - rec["t0"], 6)
            self.records.append(rec)

    # --- introspection ------------------------------------------------
    def snapshot(self) -> List[dict]:
        """The records so far (a copy — safe to mutate/serialize)."""
        return [dict(r) for r in self.records]

    def counts(self) -> Dict[str, int]:
        """``{"span:<name>"|"event:<kind>": count}`` — the compact
        summary ``benchmarks/common.write_report`` attaches."""
        out: Dict[str, int] = {}
        for r in self.records:
            k = (f"span:{r['name']}" if r["type"] == "span"
                 else f"event:{r['kind']}")
            out[k] = out.get(k, 0) + 1
        return out


_RECORDER = Recorder()


def get_recorder() -> Recorder:
    return _RECORDER


def enabled() -> bool:
    return _RECORDER.enabled


def event(kind: str, **meta) -> None:
    """Emit one event on the process recorder (no-op when disabled)."""
    _RECORDER.event(kind, **meta)


def span(name: str, **meta):
    """Open one span on the process recorder (no-op when disabled)."""
    return _RECORDER.span(name, **meta)


@contextlib.contextmanager
def recording(path: Optional[str] = None, audit: Optional["AuditLog"] = None,
              **meta):
    """Enable the process recorder for the ``with`` body.

    ``path`` exports the flight record as JSONL on exit (including the
    ``audit`` log's hash chain when one is passed); the records also
    stay on the recorder for in-process inspection until the next
    :func:`recording`.  ``meta`` lands in the export header."""
    rec = _RECORDER.start()
    try:
        yield rec
    finally:
        rec.stop()
        if path is not None:
            export_jsonl(path, recorder=rec, audit=audit, meta=meta)


# ----------------------------------------------------------------------
# On-device round telemetry — the block the engine scan accumulates
# ----------------------------------------------------------------------

def make_round_telemetry_fn(cfg):
    """Build ``tel_fn(logs) -> {name: device scalar}`` — the per-round
    telemetry block ``RoundEngine`` accumulates inside the training scan
    when ``cfg.telemetry`` is on.

    The block is a *pure function of the round's log dict* (the same
    logs the eval tail reads), so it adds reductions, never new
    computation paths: ``kept``/``tagged`` popcount the aggregator's
    keep-mask, ``c1_pass``/``c2_pass`` count clients passing each
    DiverseFL criterion against ``cfg.dfl``'s thresholds, and the
    update/guide norm summaries reduce the ``z_sq``/``g_sq`` statistics
    the DiverseFL rules already compute (and now log).  Which keys exist
    is static per config — exactly like ``make_eval_fn``'s metric set —
    so the block has a fixed structure the scan can stack.  Everything
    is int32 counts or one fp32 sqrt/mean at the end: a few dozen bytes
    per round (``fl/metrics.round_telemetry_bytes`` is the exact
    model), accumulated on device and drained at the one host sync."""
    dfl = cfg.dfl

    def tel_fn(logs):
        t: Dict[str, Any] = {}
        if "mask" in logs:
            mask = logs["mask"].astype(bool)
            if "cand" in logs:
                # async rounds: only rows that actually participated
                # (live cohort + landed stale updates) count — slot
                # rows that landed nothing are neither kept nor tagged
                cand = logs["cand"].astype(bool)
                kept = jnp.sum((mask & cand).astype(jnp.int32))
                t["kept"] = kept
                t["tagged"] = jnp.sum((cand & ~mask).astype(jnp.int32))
            else:
                kept = jnp.sum(mask.astype(jnp.int32))
                t["kept"] = kept
                t["tagged"] = jnp.int32(mask.shape[0]) - kept
        if "nonfinite" in logs:
            # the streaming fold's non-finite guard: clients whose
            # update arrived NaN/Inf and was masked to zero weight
            t["nonfinite"] = jnp.sum(
                logs["nonfinite"].astype(jnp.int32))
        for k in ("cohort", "stale_buffered", "stale_folded",
                  "stale_expired"):
            if k in logs:
                t[k] = logs[k].astype(jnp.int32)
        if "c1" in logs:
            # c1 = sign(dot): the paper's eps1=0 direction test passes
            # iff the sign is positive (Eq. 2/4)
            t["c1_pass"] = jnp.sum((logs["c1"] > 0).astype(jnp.int32))
        if "c2" in logs:
            c2 = logs["c2"]
            t["c2_pass"] = jnp.sum(
                ((c2 > dfl.eps2) & (c2 < dfl.eps3)).astype(jnp.int32))
        if "z_sq" in logs:
            zn = jnp.sqrt(logs["z_sq"].astype(jnp.float32))
            t["upd_norm_mean"] = jnp.mean(zn)
            t["upd_norm_max"] = jnp.max(zn)
        if "g_sq" in logs:
            gn = jnp.sqrt(logs["g_sq"].astype(jnp.float32))
            t["guide_norm_mean"] = jnp.mean(gn)
            t["guide_norm_max"] = jnp.max(gn)
        return t

    return tel_fn


# ----------------------------------------------------------------------
# Enclave audit log — append-only, hash-chained
# ----------------------------------------------------------------------

def _canonical(obj) -> str:
    """Deterministic JSON: the byte string the chain digests commit to."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def entry_digest(index: int, kind: str, data: dict, prev: str) -> str:
    """sha256 over (previous digest ‖ canonical entry body)."""
    body = _canonical({"index": index, "kind": kind, "data": data})
    return hashlib.sha256((prev + body).encode()).hexdigest()


@dataclasses.dataclass(frozen=True)
class AuditVerdict:
    ok: bool
    entries: int
    bad_index: int = -1          # first entry whose digest fails (-1: none)
    reason: str = ""

    def __bool__(self):
        return self.ok


class AuditLog:
    """Append-only hash-chained log of enclave-side decisions.

    Each entry is ``{"index", "kind", "data", "prev", "digest"}`` with
    ``digest = sha256(prev ‖ canonical_json({index, kind, data}))`` and
    entry 0 committing to the :data:`GENESIS` digest — so any mutation,
    deletion or reordering of a committed entry breaks every digest
    after it.  ``data`` values must be JSON-serializable scalars (the
    SecureServer only logs ids, counts, versions and measurements —
    never samples or updates).  This is the simulation analogue of
    SecFL's attested aggregation log: the aggregator cannot silently
    rewrite which clients it tagged."""

    def __init__(self):
        self.entries: List[dict] = []

    def append(self, kind: str, **data) -> dict:
        prev = self.entries[-1]["digest"] if self.entries else GENESIS
        index = len(self.entries)
        entry = {"index": index, "kind": kind, "data": data, "prev": prev,
                 "digest": entry_digest(index, kind, data, prev)}
        self.entries.append(entry)
        return entry

    @property
    def head(self) -> str:
        """The chain head digest (GENESIS when empty) — committing to it
        commits to the whole log."""
        return self.entries[-1]["digest"] if self.entries else GENESIS

    def verify(self) -> AuditVerdict:
        return verify_entries(self.entries)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.entries:
            out[e["kind"]] = out.get(e["kind"], 0) + 1
        return out


def verify_entries(entries: List[dict]) -> AuditVerdict:
    """Recompute the hash chain of a (possibly deserialized) entry list.

    Checks, per entry: the index is sequential, ``prev`` equals the
    previous entry's digest (GENESIS for entry 0), and the stored digest
    matches the recomputed one.  Returns an :class:`AuditVerdict`
    (truthy iff the chain verifies) naming the first bad entry."""
    prev = GENESIS
    for i, e in enumerate(entries):
        try:
            if e["index"] != i:
                return AuditVerdict(False, len(entries), i,
                                    f"index {e['index']} != position {i}")
            if e["prev"] != prev:
                return AuditVerdict(False, len(entries), i,
                                    "prev digest does not chain")
            want = entry_digest(i, e["kind"], e["data"], prev)
            if e["digest"] != want:
                return AuditVerdict(False, len(entries), i,
                                    "digest mismatch (entry mutated)")
            prev = e["digest"]
        except (KeyError, TypeError) as exc:
            return AuditVerdict(False, len(entries), i,
                                f"malformed entry: {exc}")
    return AuditVerdict(True, len(entries))


# ----------------------------------------------------------------------
# JSONL export / import — what launch/observe.py renders
# ----------------------------------------------------------------------

def export_jsonl(path, recorder: Optional[Recorder] = None,
                 audit: Optional[AuditLog] = None,
                 meta: Optional[dict] = None) -> None:
    """Write one recorded run as JSONL: a header line (schema version,
    wall-clock epoch, run metadata), then every span/event record, then
    the audit chain entries (``"type": "audit"``)."""
    rec = recorder if recorder is not None else _RECORDER
    lines = [{"type": "header", "schema": SCHEMA_VERSION,
              "wall0": rec.wall0, "meta": meta or {}}]
    lines += rec.snapshot()
    if audit is not None:
        lines += [{"type": "audit", **e} for e in audit.entries]
    with open(path, "w") as f:
        for line in lines:
            f.write(_canonical(line) + "\n")


def load_jsonl(path) -> Dict[str, Any]:
    """Load an exported run: ``{"header", "spans", "events", "audit"}``
    (audit entries stripped back to the shape :func:`verify_entries`
    checks)."""
    header, spans, events, audit = {}, [], [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("type")
            if kind == "header":
                header = rec
            elif kind == "span":
                spans.append(rec)
            elif kind == "event":
                events.append(rec)
            elif kind == "audit":
                audit.append({k: rec[k] for k in
                              ("index", "kind", "data", "prev", "digest")})
    return {"header": header, "spans": spans, "events": events,
            "audit": audit}
