from .small_models import softmax_regression, mlp3, small_cnn, vgg11, SmallModel
from .server import (AggregationContext, SecureServer, aggregate,
                     available_aggregators, get_aggregator,
                     register_aggregator)
from .chunking import chunked_vmap
from .compression import (Codec, available_codecs, encode_with_feedback,
                          get_codec, quantize_tree, register_codec,
                          wire_bytes)
from .faults import (FAULT_KINDS, DegenerateCohortError, FaultConfig,
                     draw_faults, make_cohort_chain, validate_cohort_chain)
from .streaming import (StreamingAggregator, fallback_reason, get_streaming,
                        register_streaming, stream_aggregate, streaming_rules,
                        tree_merge, weighted_mean_rule)
from .engine import (RoundEngine, make_round_body, make_scenario,
                     trace_counter, trace_counts)
from .simulator import (FLConfig, Federation, host_sync,
                        run_federated_sweep, run_federated_training)
from .sweep import SweepCell, SweepSpec, group_cells, structural_key
from .zoo import ZooModel, make_zoo_data, make_zoo_federation, zoo_model
from .telemetry import (AuditLog, Recorder, event, export_jsonl, get_recorder,
                        load_jsonl, recording, span, verify_entries)
from . import rsa, metrics, telemetry

