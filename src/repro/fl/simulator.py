"""Federated-learning simulator — Algorithm 1 plus every baseline server.

The round math (paper Steps 2-5) is defined once in
fl/engine.make_round_body: clients run E local-SGD iterations on fresh
minibatches, Byzantine clients corrupt data (label flip / backdoor) or
updates (gaussian / sign flip / same value / x5 scaling), then the round
is handed to the SecureServer (fl/server.py) and the aggregator
registry.

Training runs through the :class:`~repro.fl.engine.RoundEngine`: each
``eval_every`` segment of rounds compiles into one donated
``jax.lax.scan`` (one dispatch + one host sync per segment), client
local training and guiding updates are bounded to ``client_chunk``-sized
blocks, and the client axis is sharded over the mesh's data axes when
one is active.  ``FLConfig(streaming=True)`` additionally folds the
aggregation into the chunked sweep (fl/streaming.py): associative rules
never materialize the (N, D) update/guide matrices, bit-identically to
the dense path (DESIGN.md §6).  ``use_engine=False`` keeps the seed
per-round jitted loop — the benchmark baseline and the bit-for-bit
reference the engine is tested against (tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..core import DiverseFLConfig
from ..core.attacks import AttackConfig, make_byzantine_mask
from ..data.pipeline import FederatedData
from . import telemetry
from .compression import available_codecs, get_codec
from .engine import RoundEngine, make_round_body, make_scenario
from .faults import FaultConfig, init_async_state
from .metrics import BackdoorEval, comm_stats, make_backdoor_eval, make_eval_fn
from .server import KERNEL_AGG_RULES, SecureServer, available_aggregators
from .small_models import SmallModel
from .streaming import fallback_reason, get_streaming


# names come from the registry now; the tuple stays for back-compat
AGGREGATORS = available_aggregators()


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 23
    f: int = 5
    rounds: int = 100
    local_steps: int = 1                 # E
    batch_size: int = 30                 # m
    l2: float = 0.0067
    aggregator: str = "diversefl"
    attack: AttackConfig = AttackConfig()
    dfl: DiverseFLConfig = DiverseFLConfig()
    sample_frac: float = 0.01            # enclave sample s / n_j
    root_frac: float = 0.01              # FLTrust root dataset fraction
    resample_s: int = 2                  # Resampling s_R
    participation: float = 1.0           # C = ceil(participation * N) <= N
    use_kernel_stats: bool = False       # Pallas fused similarity kernel
    use_kernel_agg: bool = False         # Pallas fused Step 4+5 (masked mean)
    client_chunk: Optional[int] = None   # engine: clients in flight at once
    streaming: bool = False              # fold aggregation into the chunked
    #                                      sweep (O(chunk·D) memory); non-
    #                                      associative rules fall back dense
    stream_shards: Optional[int] = None  # streaming fold groups: None = auto
    #                                      from the mesh's data axes (1 off-
    #                                      mesh), int forces an S-way fold +
    #                                      canonical tree-merge (DESIGN.md §7);
    #                                      per-pod groups when pods > 1
    pods: Optional[int] = None           # two-tier streaming fold: None =
    #                                      auto from the mesh's pod axis (1
    #                                      off-mesh), int forces P pod-local
    #                                      folds tree-merged across pods —
    #                                      pods=1 IS the single-tier fold,
    #                                      bitwise (DESIGN.md §9)
    donate: Optional[bool] = None        # scan-carry buffer donation: None =
    #                                      auto (on wherever the backend
    #                                      supports it, i.e. off on CPU),
    #                                      True/False force it
    compression: str = "f32"             # client→server update codec
    #                                      (fl/compression.py): "f32" is the
    #                                      lossless wire format (bitwise the
    #                                      pre-compression paths), "bf16"/
    #                                      "int8" quantize at the client
    #                                      boundary with error feedback
    telemetry: bool = False              # per-round on-device telemetry
    #                                      block (fl/telemetry.py): C1/C2
    #                                      pass counts, tag popcounts, norm
    #                                      summaries accumulated in the scan
    #                                      and drained at the one host sync;
    #                                      histories stay bitwise-identical
    #                                      to telemetry=False (DESIGN.md §11)
    fault: FaultConfig = FaultConfig()   # device-malfunction model
    #                                      (fl/faults.py): straggler delay,
    #                                      dropout, intermittent corruption —
    #                                      drawn per round from the RNG
    #                                      chain, composing with the attack
    #                                      axis (DESIGN.md §13)
    cohort_participation: Optional[float] = None
    #                                      per-round cohort RESAMPLING: a
    #                                      fresh ceil(p*N)-client cohort per
    #                                      scanned round via the (R, N)
    #                                      cohort-chain scenario operand.
    #                                      None = off (the static
    #                                      `participation` selection — the
    #                                      PR-9 path, jaxpr-identical)
    staleness_buffer: int = 0            # bounded-staleness slots in the
    #                                      scan carry (O(buffer·D) pending
    #                                      slab); 0 = stragglers' updates
    #                                      expire instead of landing
    staleness_cap: int = 0               # hard staleness cap in rounds:
    #                                      updates older than the cap expire
    #                                      instead of buffering (0 = no cap)
    staleness_discount: float = 1.0      # landing weight multiplier per
    #                                      round of staleness (discount**age
    #                                      rides the fold's valid channel)
    eval_every: int = 10
    seed: int = 0

    def __post_init__(self):
        # shape knobs fail here, with names, instead of deep inside the
        # chunked fold as an inscrutable reshape/shape error
        if self.client_chunk is not None and (
                not isinstance(self.client_chunk, int)
                or isinstance(self.client_chunk, bool)
                or self.client_chunk < 1):
            raise ValueError(
                f"client_chunk must be None or a positive int (clients in "
                f"flight at once), got {self.client_chunk!r}")
        if self.stream_shards is not None and (
                not isinstance(self.stream_shards, int)
                or isinstance(self.stream_shards, bool)
                or self.stream_shards < 1):
            raise ValueError(
                f"stream_shards must be None (auto from the mesh) or a "
                f"positive int (forced fold groups), got "
                f"{self.stream_shards!r}")
        if self.pods is not None and (
                not isinstance(self.pods, int)
                or isinstance(self.pods, bool)
                or self.pods < 1):
            raise ValueError(
                f"pods must be None (auto from the mesh's pod axis) or a "
                f"positive int (forced two-tier pod count), got "
                f"{self.pods!r}")
        if self.pods is not None and self.pods > 1 and not self.streaming:
            raise ValueError(
                f"pods={self.pods} requires streaming=True: the two-tier "
                f"aggregation is an association of the streaming AggState "
                f"fold (DESIGN.md §9) — the dense (N, D) path has no pod "
                f"tiers and would silently ignore the knob")
        if self.pods is not None and self.pods > 1:
            if self.client_chunk is None:
                raise ValueError(
                    f"pods={self.pods} requires client_chunk: without "
                    f"chunking the round is a single block and there is "
                    f"nothing to partition across pods")
            k = -(-self.n_selected // min(self.client_chunk,
                                          self.n_selected))
            if self.pods > k or k % self.pods:
                raise ValueError(
                    f"pods={self.pods} cannot tile the padded block count "
                    f"{k} (= ceil(n_selected {self.n_selected} / "
                    f"client_chunk {self.client_chunk})); pick a "
                    f"client_chunk so the blocks divide evenly across pods")
        if self.use_kernel_agg and self.aggregator not in KERNEL_AGG_RULES:
            raise ValueError(
                f"use_kernel_agg=True requires a masked/weighted-mean "
                f"family aggregator {KERNEL_AGG_RULES}; {self.aggregator!r} "
                f"never routes through the fused masked-agg kernel, so the "
                f"flag would be silently ignored")
        if (self.streaming and self.use_kernel_stats
                and not self.use_kernel_agg
                and self.aggregator == "diversefl"):
            raise ValueError(
                "use_kernel_stats=True is unreachable on the streaming "
                "row-fold path (per-client statistics are computed inline "
                "during the fold); combine it with use_kernel_agg=True for "
                "the fused per-block kernel path, or drop the flag")
        if self.compression not in available_codecs():
            raise ValueError(
                f"compression={self.compression!r} is not a registered "
                f"codec; available: {available_codecs()} "
                f"(fl/compression.py)")
        if (not get_codec(self.compression).lossless
                and self.use_kernel_agg and not self.streaming):
            raise ValueError(
                f"compression={self.compression!r} with use_kernel_agg=True "
                f"requires streaming=True: the fused dequantize-and-fold "
                f"kernel IS the streaming block fold — the dense path "
                f"decodes updates before aggregation, so the kernel flag "
                f"would silently buy no fusion (DESIGN.md §10)")
        # --- async knobs (DESIGN.md §13) -------------------------------
        if not isinstance(self.fault, FaultConfig):
            raise ValueError(
                f"fault must be a fl.faults.FaultConfig, got "
                f"{type(self.fault).__name__}")
        if self.cohort_participation is not None:
            p = self.cohort_participation
            if isinstance(p, bool) or not isinstance(p, (int, float)) \
                    or not (0.0 < float(p) <= 1.0):
                raise ValueError(
                    f"cohort_participation must be None (static cohort) or "
                    f"a fraction in (0, 1] — a cohort that selects zero "
                    f"clients every round is degenerate (0/0 weighted "
                    f"mean); got {p!r}")
        for name in ("staleness_buffer", "staleness_cap"):
            v = getattr(self, name)
            if isinstance(v, bool) or not isinstance(v, int) or v < 0:
                raise ValueError(
                    f"{name} must be a non-negative int (rounds/slots), "
                    f"got {v!r}")
        if not (0.0 < float(self.staleness_discount) <= 1.0):
            raise ValueError(
                f"staleness_discount must be in (0, 1] (landing weight "
                f"multiplier per round of staleness), got "
                f"{self.staleness_discount!r}")
        if self.async_rounds:
            if self.participation != 1.0:
                raise ValueError(
                    f"async rounds (fault/cohort/staleness knobs) replace "
                    f"the static participation selection with the per-round "
                    f"cohort chain — set participation=1.0 and use "
                    f"cohort_participation={self.participation} for "
                    f"resampled partial participation (DESIGN.md §13)")
            if not self.streaming or get_streaming(self.aggregator) is None:
                why = ("streaming=False" if not self.streaming else
                       f"aggregator {self.aggregator!r} has no streaming "
                       f"rule ({fallback_reason(self.aggregator)})")
                raise ValueError(
                    f"async rounds fold per-round cohorts, faulty clients "
                    f"and landed stale updates through the streaming "
                    f"AggState monoid's weight channel, but {why}: the "
                    f"dense path has no per-client weight channel to carry "
                    f"the cohort/staleness masks (DESIGN.md §13)")
            if not get_codec(self.compression).lossless:
                raise ValueError(
                    f"async rounds cannot compose with the lossy "
                    f"compression={self.compression!r}: error-feedback "
                    f"residuals assume every client transmits every round, "
                    f"but cohort resampling/dropout makes transmission "
                    f"intermittent — the residual would silently go stale "
                    f"(DESIGN.md §13).  Use compression='f32'")

    @property
    def async_rounds(self) -> bool:
        """True when any async knob engages the per-round cohort / fault
        / staleness machinery.  False means the round body traces the
        exact PR-9 jaxpr — the structural half of the §13 bitwise
        contract."""
        return (self.fault.kind != "none"
                or self.cohort_participation is not None
                or self.staleness_buffer > 0)

    @property
    def n_selected(self) -> int:
        return max(1, min(self.n_clients,
                          math.ceil(self.participation * self.n_clients)))

    def validate_model_sharding(self, d: int, model_shards: int,
                                streaming_fallback: Optional[str] = None,
                                leaf_sizes: Optional[tuple] = None):
        """Named errors for knobs that cannot compose with a tensor-
        (model-axis-)sharded run — checked by the engine once the flat
        model dim ``d`` is known (it needs the params, so it cannot live
        in ``__post_init__``).  ``model_shards`` is the mesh's model-axis
        size (sharding.model_shard_count); ``streaming_fallback`` the
        engine's resolved fallback reason, so a streaming=True config
        whose rule silently fell back dense still fails loudly here.
        No-op when ``model_shards <= 1`` — every existing config is
        untouched (DESIGN.md §12)."""
        if model_shards <= 1:
            return
        if not self.streaming or streaming_fallback is not None:
            why = (f"aggregator {self.aggregator!r} cannot stream "
                   f"({streaming_fallback})" if streaming_fallback
                   else "streaming=False")
            raise ValueError(
                f"model-sharded run (model_shards={model_shards}) requires "
                f"the streaming fold, but {why}: the dense fallback "
                f"materializes the full (n_selected={self.n_selected}, "
                f"D={d}) update matrix — at tensor-parallel model sizes "
                f"that is exactly the O(N·D) term the streaming AggState "
                f"exists to remove (DESIGN.md §6, §12).  Use streaming=True "
                f"with a streaming-capable aggregator "
                f"(fl/streaming.streaming_rules())")
        if self.use_kernel_agg or self.use_kernel_stats:
            flag = "use_kernel_agg" if self.use_kernel_agg \
                else "use_kernel_stats"
            raise ValueError(
                f"{flag}=True cannot compose with a model-sharded run "
                f"(model_shards={model_shards}): the Pallas fold/stats "
                f"kernels are single-device programs over an unsharded "
                f"(chunk, D) block — under GSPMD they would force a "
                f"cross-model-axis gather of the very matrix the sharding "
                f"splits.  Drop the kernel flags (the in-fold axis=-1 "
                f"reductions shard for free)")
        codec = get_codec(self.compression)
        if not codec.lossless and leaf_sizes is not None:
            bad = [s for s in leaf_sizes if s % model_shards]
            if bad:
                raise ValueError(
                    f"compression={self.compression!r} (lossy) on a "
                    f"model-sharded run needs every parameter tensor to "
                    f"tile the model axis — the blocked (ms, L) layout "
                    f"must be pad-free so the (N, D) error-feedback "
                    f"residual plane reshapes losslessly onto the update "
                    f"blocks — but {len(bad)} leaf(s) (e.g. size "
                    f"{bad[0]}) are not multiples of model_shards="
                    f"{model_shards} (DESIGN.md §12)")
        if codec.qblock is not None:
            if d % model_shards:
                raise ValueError(
                    f"compression={self.compression!r} on a model-sharded "
                    f"run needs the flat dim to tile the model axis: "
                    f"D={d} % model_shards={model_shards} != 0, so the "
                    f"per-block scale groups would straddle shard "
                    f"boundaries")
            local = d // model_shards
            if local % codec.qblock:
                raise ValueError(
                    f"compression={self.compression!r} quantizes in "
                    f"QBLOCK={codec.qblock} groups along the flat dim, but "
                    f"the local model shard D/model_shards = {d}/"
                    f"{model_shards} = {local} is not a multiple of "
                    f"{codec.qblock}: wire blocks would straddle shard "
                    f"boundaries and every encode/decode would pay a "
                    f"cross-model-axis reshard.  Pick a model_shards (or "
                    f"model size) with QBLOCK | D/model_shards")


@dataclasses.dataclass
class Federation:
    model: SmallModel
    data: FederatedData
    test_x: jnp.ndarray
    test_y: jnp.ndarray
    byz_mask: jnp.ndarray                   # (N,) bool — ground truth
    server: SecureServer                    # owns the enclave + registry
    root_x: Optional[jnp.ndarray] = None    # FLTrust root dataset
    root_y: Optional[jnp.ndarray] = None
    _bd_eval: Optional[BackdoorEval] = dataclasses.field(
        default=None, repr=False)           # cached trigger-stamped test set

    @property
    def enclave(self):
        return self.server.enclave

    def backdoor_eval(self, acfg: AttackConfig) -> BackdoorEval:
        """The trigger-stamped backdoor test set, built once per
        federation (per source/target pair) — every eval after the first
        is a masked reduction over the cached stamp, not a re-stamp."""
        bd = self._bd_eval
        if bd is None or (bd.source_class, bd.target_class) != \
                (acfg.source_class, acfg.target_class):
            bd = make_backdoor_eval(self.test_x, self.test_y, acfg)
            self._bd_eval = bd
        return bd

    @classmethod
    def create(cls, model: SmallModel, data: FederatedData, test_x, test_y,
               cfg: FLConfig, key):
        k1, k2 = jax.random.split(key)
        byz = make_byzantine_mask(data.n_clients, cfg.f)
        # Steps 0-1: attested server, clients seal their shared samples.
        # No plaintext copy is kept — guide batches are only reachable by
        # unsealing through the SecureServer.
        server = SecureServer()
        gx, gy = data.enclave_samples(k1, cfg.sample_frac)
        for j in range(data.n_clients):
            server.ingest_samples(j, gx[j], gy[j])
        del gx, gy
        # FLTrust root dataset: random subset of the union of client data
        flat_x = data.x.reshape((-1,) + data.x.shape[2:])
        flat_y = data.y.reshape(-1)
        n_root = max(1, int(cfg.root_frac * flat_y.shape[0]))
        idx = jax.random.choice(k2, flat_y.shape[0], (n_root,), replace=False)
        return cls(model=model, data=data, test_x=test_x, test_y=test_y,
                   byz_mask=byz, server=server,
                   root_x=flat_x[idx], root_y=flat_y[idx])


# ----------------------------------------------------------------------

def _build_round_step(model: SmallModel, fed: Federation, cfg: FLConfig):
    """The seed per-round path: one jitted dispatch per round.

    Kept as the benchmark baseline (benchmarks/engine_bench.py) and as
    the reference the scan engine must reproduce bit-for-bit; it jits
    the very same round body the engine scans."""
    body = make_round_body(model, fed, cfg, client_chunk=cfg.client_chunk)
    if cfg.async_rounds:
        # the async body reads the cohort chain off the scenario; baking
        # it as a jit constant is fine here — this path re-jits per
        # config anyway (the engine threads it as a traced operand)
        scen = make_scenario(cfg, fed)
        return jax.jit(lambda carry, key, lr: body(carry, key, lr,
                                                   scen=scen))
    return jax.jit(lambda params, key, lr: body(params, key, lr))


def host_sync(tree):
    """The simulator's single device→host materialization point.

    Every value ``run_federated_training`` moves off the device flows
    through here — the legacy host-eval loop once per eval segment, the
    one-dispatch path exactly once per training run.  Keeping one choke
    point makes the sync count *measurable*: benchmarks/dispatch_bench
    wraps this function with a counter and runs training under
    ``jax.transfer_guard_device_to_host("disallow_explicit")``, so on
    accelerator backends a host read that bypasses it raises instead of
    hiding (on CPU, where arrays are host-resident, the guard is inert
    and the counter is the whole measurement).

    When the flight recorder is on, each sync emits a ``sync`` event
    carrying the bytes moved (sum of leaf ``nbytes``) and the fetch wall
    time — the one-sync contract becomes *visible* in a recorded run,
    not just counted in the dispatch bench."""
    rec = telemetry.get_recorder()
    if not rec.enabled:
        with jax.transfer_guard_device_to_host("allow"):
            return jax.device_get(tree)
    leaves = jax.tree.leaves(tree)
    nbytes = int(sum(getattr(x, "nbytes", 0) for x in leaves))
    t0 = rec.now()
    with jax.transfer_guard_device_to_host("allow"):
        out = jax.device_get(tree)
    rec.event("sync", bytes=nbytes, leaves=len(leaves),
              dur=round(rec.now() - t0, 6))
    return out


def drain_round_telemetry(server, tel_host, *, uplink_bytes=None, cell=None):
    """Host-side drain of the engine's per-round telemetry block.

    ``tel_host`` is the already-synced ``"_tel"`` dict (leaves shaped
    (R,)) popped off the metric buffer *after* the run's one host sync —
    this function only reformats host data, it never touches the device.
    Each round becomes (a) a ``round`` event on the flight recorder
    (C1/C2 pass counts, tag popcounts, norm summaries, uplink bytes) and
    (b) a ``round_tags`` entry in the SecureServer's hash-chained audit
    log — the enclave's committed record of *which counts it tagged*,
    the thing SecFL-style deployments must be able to prove they did not
    rewrite."""
    if not tel_host:
        return
    n = len(next(iter(tel_host.values())))
    rec = telemetry.get_recorder()
    for r in range(n):
        row = {}
        for k, v in tel_host.items():
            x = v[r]
            row[k] = x.item() if hasattr(x, "item") else x
        if uplink_bytes is not None:
            row["uplink_bytes"] = uplink_bytes
        if cell is not None:
            row["cell"] = cell
        if rec.enabled:
            rec.event("round", index=r + 1, **row)
        tags = {k: row[k] for k in ("kept", "tagged", "c1_pass", "c2_pass")
                if k in row}
        if tags:
            if cell is not None:
                tags["cell"] = cell
            server.record_round_tags(r + 1, **tags)
        # async control path: the hash chain commits the per-round cohort
        # size and every staleness decision (ISSUE 10 satellite)
        extra = {} if cell is None else {"cell": cell}
        if "cohort" in row:
            server.record_cohort_resample(r + 1, int(row["cohort"]), **extra)
        for decision in ("buffered", "folded", "expired"):
            k = f"stale_{decision}"
            if k in row and int(row[k]) > 0:
                server.record_stale(r + 1, decision, int(row[k]), **extra)


def _record_eval(history, i, metrics, log_every):
    """Append one eval point's host-side metric dict to the history.

    The dict is make_eval_fn's output verbatim — every key it computes
    is recorded, so adding a metric there needs no change here."""
    history["round"].append(i)
    for k, v in metrics.items():
        history.setdefault(k, []).append(v)
    if log_every and i % log_every == 0:
        print(f"  round {i:5d} acc={metrics['acc']:.4f}")


def _lr_vector(lr_schedule: Callable, rounds: int) -> jnp.ndarray:
    """Evaluate the schedule for rounds 1..R as one device (R,) vector.

    The legacy loop called ``float(lr_schedule(i))`` per round — R tiny
    device→host transfers before training even dispatched (and a
    transfer-guard violation on accelerator backends).  One vmap keeps
    the values on device, bit-identical per element for the repo's
    elementwise-jnp schedules (repro/optim/schedules.py).  A schedule
    with host control flow (``0.1 if i < 100 else 0.01``) cannot trace;
    it keeps working through the legacy eager per-round evaluation —
    slower, but the pre-existing public contract."""
    ix = jnp.arange(1, rounds + 1)
    try:
        return jax.vmap(lr_schedule)(ix).astype(jnp.float32)
    except (jax.errors.JAXTypeError, TypeError):
        return jnp.asarray([float(lr_schedule(i))
                            for i in range(1, rounds + 1)], jnp.float32)


def run_federated_training(model: SmallModel, fed: Federation, cfg: FLConfig,
                           lr_schedule: Callable, log_every: int = 0,
                           use_engine: bool = True, host_eval: bool = False,
                           engine: Optional[RoundEngine] = None) -> Dict:
    """Run ``cfg.rounds`` federated rounds; returns the metric history.

    Engine mode (the default) is **one-dispatch**: the whole run
    compiles into a single outer scan over eval segments with the eval
    metrics accumulated on device (`RoundEngine.run_training`), and the
    host syncs exactly once at the end.  ``host_eval=True`` keeps the
    legacy per-segment loop — one dispatch and one host sync per eval
    segment, the bitwise reference the in-scan eval is tested against.
    ``use_engine=False`` keeps the seed per-round jitted loop (benchmark
    baseline).  All three paths evaluate through the same jitted metric
    functions (fl/metrics.make_eval_fn), so their histories agree
    bit-for-bit.  ``engine`` reuses a prebuilt (already-compiled)
    ``RoundEngine`` instead of constructing one per call — what lets
    benchmarks time repeat runs without retracing.

    ``log_every`` prints eval lines as they reach the host: live per
    segment on the ``host_eval=True`` and seed-loop paths, but on the
    one-dispatch default everything is on device until the single final
    sync, so the lines appear together at the end — use
    ``host_eval=True`` when watching a long run interactively.
    """
    key = jax.random.PRNGKey(cfg.seed)
    params = model.init(jax.random.PRNGKey(cfg.seed + 1))
    history = {"round": [], "acc": [], "mask_tpr": [], "mask_fpr": [],
               "c1c2": []}

    if use_engine and engine is None:
        engine = RoundEngine(model, fed, cfg)

    lrs_all = _lr_vector(lr_schedule, cfg.rounds)
    # the run's traced operands (attack magnitudes, Byzantine mask):
    # derived from *this call's* cfg/fed, not the engine's, so reusing a
    # prebuilt engine with a magnitude-only cfg change is a cache hit,
    # never a stale constant (tests/test_sweep.py pins the no-retrace)
    scen = make_scenario(cfg, fed) if use_engine else None

    # d from aval metadata (p.size is the GLOBAL size of a sharded
    # array — no device gather, no host sync); the wire stats price the
    # per-shard encoding when the engine runs tensor-sharded
    d_model = sum(p.size for p in jax.tree.leaves(params))
    cstats = comm_stats(
        cfg, d_model,
        model_shards=engine.model_shards if engine is not None else 1)
    run_span = telemetry.span(
        "run_training", n_clients=cfg.n_clients, rounds=cfg.rounds,
        aggregator=cfg.aggregator, attack=cfg.attack.kind, d=int(d_model),
        chunk=cfg.client_chunk, pods=cfg.pods, codec=cfg.compression,
        streaming=bool(getattr(cfg, "streaming", False)),
        mode=("one-dispatch" if use_engine and not host_eval
              else "host-eval" if use_engine else "per-round"))

    if use_engine and not host_eval:
        with run_span:
            with telemetry.span("dispatch"):
                params, key, metrics, eval_rounds = engine.run_training(
                    params, key, lrs_all, scen)
            if metrics is not None:                    # rounds >= 1
                host = host_sync(metrics)              # THE host sync
                # the reserved telemetry block rides the same sync and is
                # drained here — it never enters the history
                drain_round_telemetry(
                    fed.server, host.pop("_tel", None),
                    uplink_bytes=cstats["uplink_bytes_per_round"])
                for s, i in enumerate(eval_rounds):
                    _record_eval(history, i,
                                 {k: v[s] for k, v in host.items()},
                                 log_every)
    elif use_engine:
        # run_segment carries (params, resid) under lossy compression —
        # chaining the returned carry is what keeps error feedback
        # flowing across eval segments; eval reads the params inside
        with run_span:
            carry = engine.init_carry(params)
            i = 0
            while i < cfg.rounds:
                n = min(engine.eval_every, cfg.rounds - i)
                carry, key, logs = engine.run_segment(carry, key,
                                                      lrs_all[i:i + n], scen)
                i += n
                _record_eval(
                    history, i,
                    host_sync(engine.eval_metrics(
                        engine.carry_params(carry), logs)),
                    log_every)
            params = engine.carry_params(carry)
    else:
        with run_span:
            round_step = _build_round_step(model, fed, cfg)
            eval_fn = jax.jit(make_eval_fn(model, fed, cfg))
            lossy = not get_codec(cfg.compression).lossless
            d = sum(p.size for p in jax.tree.leaves(params))
            if lossy:
                carry = (params, jnp.zeros((cfg.n_clients, d), jnp.float32))
            elif cfg.async_rounds:
                # async and lossy are mutually exclusive (__post_init__),
                # so the carry is unambiguous: (params, async state)
                carry = (params, init_async_state(cfg, (d,)))
            else:
                carry = params
            wrapped = lossy or cfg.async_rounds
            for i in range(1, cfg.rounds + 1):
                key, sub = jax.random.split(key)
                carry, logs = round_step(carry, sub, lrs_all[i - 1])
                params = carry[0] if wrapped else carry
                if i % cfg.eval_every == 0 or i == cfg.rounds:
                    _record_eval(history, i,
                                 host_sync(eval_fn(params, logs)), log_every)

    history["final_acc"] = history["acc"][-1] if history["acc"] else float("nan")
    history["params"] = params
    # why a run fell off the streaming path (None when it did not) — on
    # the history, not just the engine instance, so sweep cells and saved
    # histories keep the reason (ISSUE 8 satellite)
    history["streaming_fallback"] = engine.streaming_fallback \
        if engine is not None else (
            fallback_reason(cfg.aggregator)
            if getattr(cfg, "streaming", False)
            and get_streaming(cfg.aggregator) is None else None)
    history.update(cstats)
    return history


def run_federated_sweep(model: SmallModel, fed: Federation, spec,
                        lr_schedule: Optional[Callable] = None,
                        log_every: int = 0) -> list:
    """Run a whole experiment grid batched: the sweep counterpart of
    :func:`run_federated_training`.

    ``spec`` is a :class:`~repro.fl.sweep.SweepSpec` — a grid of seeds,
    Byzantine counts/masks, attack magnitudes, learning-rate schedules
    and participation levels over a base config.  Cells are partitioned
    into *structural groups* (same trace → same compiled program) and
    each group executes as one ``jax.vmap`` of the one-dispatch training
    program over a stacked scenario axis: one compile and one
    ``host_sync`` per group instead of per cell (fl/sweep.py,
    DESIGN.md §8).  Returns one history dict per cell, in ``spec.cells()``
    order, each bitwise-equal to running that cell solo through
    :func:`run_federated_training` against a federation created with the
    cell's config and the same federation key as ``fed``."""
    from .sweep import execute_sweep    # deferred: sweep imports this module
    return execute_sweep(model, fed, spec, lr_schedule=lr_schedule,
                         log_every=log_every)
