"""Federated-learning simulator — Algorithm 1 plus every baseline server.

The round math (paper Steps 2-5) is defined once in
fl/engine.make_round_body: clients run E local-SGD iterations on fresh
minibatches, Byzantine clients corrupt data (label flip / backdoor) or
updates (gaussian / sign flip / same value / x5 scaling), then the round
is handed to the SecureServer (fl/server.py) and the aggregator
registry.

Training runs through the :class:`~repro.fl.engine.RoundEngine`: each
``eval_every`` segment of rounds compiles into one donated
``jax.lax.scan`` (one dispatch + one host sync per segment), client
local training and guiding updates are bounded to ``client_chunk``-sized
blocks, and the client axis is sharded over the mesh's data axes when
one is active.  ``FLConfig(streaming=True)`` additionally folds the
aggregation into the chunked sweep (fl/streaming.py): associative rules
never materialize the (N, D) update/guide matrices, bit-identically to
the dense path (DESIGN.md §6).  ``use_engine=False`` keeps the seed
per-round jitted loop — the benchmark baseline and the bit-for-bit
reference the engine is tested against (tests/test_engine.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import DiverseFLConfig
from ..core.attacks import AttackConfig, make_byzantine_mask
from ..data.pipeline import FederatedData
from .engine import RoundEngine, make_round_body
from .server import KERNEL_AGG_RULES, SecureServer, available_aggregators
from .small_models import SmallModel


# names come from the registry now; the tuple stays for back-compat
AGGREGATORS = available_aggregators()


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 23
    f: int = 5
    rounds: int = 100
    local_steps: int = 1                 # E
    batch_size: int = 30                 # m
    l2: float = 0.0067
    aggregator: str = "diversefl"
    attack: AttackConfig = AttackConfig()
    dfl: DiverseFLConfig = DiverseFLConfig()
    sample_frac: float = 0.01            # enclave sample s / n_j
    root_frac: float = 0.01              # FLTrust root dataset fraction
    resample_s: int = 2                  # Resampling s_R
    participation: float = 1.0           # C = ceil(participation * N) <= N
    use_kernel_stats: bool = False       # Pallas fused similarity kernel
    use_kernel_agg: bool = False         # Pallas fused Step 4+5 (masked mean)
    client_chunk: Optional[int] = None   # engine: clients in flight at once
    streaming: bool = False              # fold aggregation into the chunked
    #                                      sweep (O(chunk·D) memory); non-
    #                                      associative rules fall back dense
    eval_every: int = 10
    seed: int = 0

    def __post_init__(self):
        if self.use_kernel_agg and self.aggregator not in KERNEL_AGG_RULES:
            raise ValueError(
                f"use_kernel_agg=True requires a masked/weighted-mean "
                f"family aggregator {KERNEL_AGG_RULES}; {self.aggregator!r} "
                f"never routes through the fused masked-agg kernel, so the "
                f"flag would be silently ignored")
        if (self.streaming and self.use_kernel_stats
                and not self.use_kernel_agg
                and self.aggregator == "diversefl"):
            raise ValueError(
                "use_kernel_stats=True is unreachable on the streaming "
                "row-fold path (per-client statistics are computed inline "
                "during the fold); combine it with use_kernel_agg=True for "
                "the fused per-block kernel path, or drop the flag")

    @property
    def n_selected(self) -> int:
        return max(1, min(self.n_clients,
                          math.ceil(self.participation * self.n_clients)))


@dataclasses.dataclass
class Federation:
    model: SmallModel
    data: FederatedData
    test_x: jnp.ndarray
    test_y: jnp.ndarray
    byz_mask: jnp.ndarray                   # (N,) bool — ground truth
    server: SecureServer                    # owns the enclave + registry
    root_x: Optional[jnp.ndarray] = None    # FLTrust root dataset
    root_y: Optional[jnp.ndarray] = None

    @property
    def enclave(self):
        return self.server.enclave

    @classmethod
    def create(cls, model: SmallModel, data: FederatedData, test_x, test_y,
               cfg: FLConfig, key):
        k1, k2 = jax.random.split(key)
        byz = make_byzantine_mask(data.n_clients, cfg.f)
        # Steps 0-1: attested server, clients seal their shared samples.
        # No plaintext copy is kept — guide batches are only reachable by
        # unsealing through the SecureServer.
        server = SecureServer()
        gx, gy = data.enclave_samples(k1, cfg.sample_frac)
        for j in range(data.n_clients):
            server.ingest_samples(j, gx[j], gy[j])
        del gx, gy
        # FLTrust root dataset: random subset of the union of client data
        flat_x = data.x.reshape((-1,) + data.x.shape[2:])
        flat_y = data.y.reshape(-1)
        n_root = max(1, int(cfg.root_frac * flat_y.shape[0]))
        idx = jax.random.choice(k2, flat_y.shape[0], (n_root,), replace=False)
        return cls(model=model, data=data, test_x=test_x, test_y=test_y,
                   byz_mask=byz, server=server,
                   root_x=flat_x[idx], root_y=flat_y[idx])


# ----------------------------------------------------------------------

def _build_round_step(model: SmallModel, fed: Federation, cfg: FLConfig):
    """The seed per-round path: one jitted dispatch per round.

    Kept as the benchmark baseline (benchmarks/engine_bench.py) and as
    the reference the scan engine must reproduce bit-for-bit; it jits
    the very same round body the engine scans."""
    body = make_round_body(model, fed, cfg, client_chunk=cfg.client_chunk)
    return jax.jit(lambda params, key, lr: body(params, key, lr))


def _record_eval(model, fed, history, params, logs, i, log_every):
    acc = model.accuracy(params, fed.test_x, fed.test_y)
    history["round"].append(i)
    history["acc"].append(acc)
    byz = np.asarray(logs["byz"])
    if "mask" in logs:
        mask = np.asarray(logs["mask"])
        flagged = ~mask
        tpr = flagged[byz].mean() if byz.any() else 1.0
        fpr = flagged[~byz].mean() if (~byz).any() else 0.0
        history["mask_tpr"].append(float(tpr))
        history["mask_fpr"].append(float(fpr))
    if "c1c2" in logs:
        history["c1c2"].append(np.asarray(logs["c1c2"]))
    if log_every and i % log_every == 0:
        print(f"  round {i:5d} acc={acc:.4f}")


def run_federated_training(model: SmallModel, fed: Federation, cfg: FLConfig,
                           lr_schedule: Callable, log_every: int = 0,
                           use_engine: bool = True) -> Dict:
    key = jax.random.PRNGKey(cfg.seed)
    params = model.init(jax.random.PRNGKey(cfg.seed + 1))
    history = {"round": [], "acc": [], "mask_tpr": [], "mask_fpr": [],
               "c1c2": []}

    if use_engine:
        engine = RoundEngine(model, fed, cfg)
        i = 0
        while i < cfg.rounds:
            n = min(cfg.eval_every, cfg.rounds - i)
            lrs = [float(lr_schedule(r)) for r in range(i + 1, i + n + 1)]
            params, key, logs = engine.run_segment(params, key, lrs)
            i += n
            _record_eval(model, fed, history, params, logs, i, log_every)
    else:
        round_step = _build_round_step(model, fed, cfg)
        for i in range(1, cfg.rounds + 1):
            key, sub = jax.random.split(key)
            lr = float(lr_schedule(i))
            params, logs = round_step(params, sub, lr)
            if i % cfg.eval_every == 0 or i == cfg.rounds:
                _record_eval(model, fed, history, params, logs, i, log_every)

    history["final_acc"] = history["acc"][-1] if history["acc"] else float("nan")
    history["params"] = params
    return history
