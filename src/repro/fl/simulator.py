"""Federated-learning simulator — Algorithm 1 plus every baseline server.

One jitted ``round_step`` executes the paper's Steps 2–5:
  clients (vmapped) run E local-SGD iterations on fresh minibatches,
  Byzantine clients corrupt data (label flip / backdoor) or updates
  (gaussian / sign flip / same value / x5 scaling), then the round is
  handed to the SecureServer (fl/server.py): guiding updates come from
  the enclave's *unsealed* sample cache, and the aggregation rule —
  DiverseFL's C1/C2 criteria + masked mean (Eq. 6) or any registered
  comparison rule — is dispatched through the aggregator registry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import DiverseFLConfig, guiding_update
from ..core import aggregators as agg
from ..core.attacks import (AttackConfig, UPDATE_ATTACKS, attack_update,
                            flip_labels, poison_backdoor, make_byzantine_mask)
from ..data.pipeline import FederatedData
from .server import (AggregationContext, SecureServer, available_aggregators,
                     get_aggregator)
from .small_models import SmallModel


# names come from the registry now; the tuple stays for back-compat
AGGREGATORS = available_aggregators()


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 23
    f: int = 5
    rounds: int = 100
    local_steps: int = 1                 # E
    batch_size: int = 30                 # m
    l2: float = 0.0067
    aggregator: str = "diversefl"
    attack: AttackConfig = AttackConfig()
    dfl: DiverseFLConfig = DiverseFLConfig()
    sample_frac: float = 0.01            # enclave sample s / n_j
    root_frac: float = 0.01              # FLTrust root dataset fraction
    resample_s: int = 2                  # Resampling s_R
    participation: float = 1.0           # C = ceil(participation * N) <= N
    use_kernel_stats: bool = False       # Pallas fused similarity kernel
    use_kernel_agg: bool = False         # Pallas fused Step 4+5 (masked mean)
    eval_every: int = 10
    seed: int = 0

    @property
    def n_selected(self) -> int:
        return max(1, min(self.n_clients,
                          round(self.participation * self.n_clients)))


@dataclasses.dataclass
class Federation:
    model: SmallModel
    data: FederatedData
    test_x: jnp.ndarray
    test_y: jnp.ndarray
    byz_mask: jnp.ndarray                   # (N,) bool — ground truth
    server: SecureServer                    # owns the enclave + registry
    root_x: Optional[jnp.ndarray] = None    # FLTrust root dataset
    root_y: Optional[jnp.ndarray] = None

    @property
    def enclave(self):
        return self.server.enclave

    @classmethod
    def create(cls, model: SmallModel, data: FederatedData, test_x, test_y,
               cfg: FLConfig, key):
        k1, k2, k3 = jax.random.split(key, 3)
        byz = make_byzantine_mask(data.n_clients, cfg.f)
        # Steps 0-1: attested server, clients seal their shared samples.
        # No plaintext copy is kept — guide batches are only reachable by
        # unsealing through the SecureServer.
        server = SecureServer()
        gx, gy = data.enclave_samples(k1, cfg.sample_frac)
        for j in range(data.n_clients):
            server.ingest_samples(j, gx[j], gy[j])
        del gx, gy
        # FLTrust root dataset: random subset of the union of client data
        flat_x = data.x.reshape((-1,) + data.x.shape[2:])
        flat_y = data.y.reshape(-1)
        n_root = max(1, int(cfg.root_frac * flat_y.shape[0]))
        idx = jax.random.choice(k2, flat_y.shape[0], (n_root,), replace=False)
        return cls(model=model, data=data, test_x=test_x, test_y=test_y,
                   byz_mask=byz, server=server,
                   root_x=flat_x[idx], root_y=flat_y[idx])


# ----------------------------------------------------------------------

def _build_round_step(model: SmallModel, fed: Federation, cfg: FLConfig):
    E, m = cfg.local_steps, cfg.batch_size
    acfg = cfg.attack
    n_classes = fed.data.n_classes
    entry = get_aggregator(cfg.aggregator)   # fails fast on unknown rules
    # Unsealed once here, cached device-side: the jitted round step closes
    # over stable arrays while every byte still flows through the enclave.
    all_guide_x, all_guide_y = fed.server.guide_batches()

    def grad_fn(params, batch):
        x, y = batch
        return jax.grad(lambda p: model.loss(p, x, y, cfg.l2))(params)

    def client_update(params, xs, ys, lr):
        """xs: (E, m, ...) — E local SGD iterations, fresh batch each."""
        def step(theta, b):
            g = grad_fn(theta, b)
            return jax.tree.map(lambda t, gg: t - lr * gg, theta, g), None
        theta, _ = jax.lax.scan(step, params, (xs, ys))
        return jax.tree.map(lambda a, b: a - b, params, theta)

    def guide_update_one(params, gx, gy, lr):
        return guiding_update(params, (gx, gy), grad_fn, lr, E)

    C = cfg.n_selected

    @jax.jit
    def round_step(params, key, lr):
        kb, ka, kr, ks = jax.random.split(key, 4)
        xb, yb = fed.data.minibatch(kb, E * m)
        xb = xb.reshape((cfg.n_clients, E, m) + xb.shape[2:])
        yb = yb.reshape((cfg.n_clients, E, m))
        # Step 2 preamble: server samples the participating subset S^i
        sel = jax.random.choice(ks, cfg.n_clients, (C,), replace=False) \
            if C < cfg.n_clients else jnp.arange(cfg.n_clients)
        xb, yb = xb[sel], yb[sel]
        byz = fed.byz_mask[sel]
        guide_x, guide_y = all_guide_x[sel], all_guide_y[sel]

        # ---- data-level attacks ----
        if acfg.kind == "label_flip":
            yb = jnp.where(byz[:, None, None], flip_labels(yb, n_classes), yb)
        elif acfg.kind == "backdoor":
            def poison(xc, yc):
                xf = xc.reshape((E * m,) + xc.shape[2:])
                yf = yc.reshape(E * m)
                xp, yp = poison_backdoor(xf, yf, acfg)
                return xp.reshape(xc.shape), yp.reshape(yc.shape)
            xp, yp = jax.vmap(poison)(xb, yb)
            sel = byz.reshape((-1,) + (1,) * (xb.ndim - 1))
            xb = jnp.where(sel, xp, xb)
            yb = jnp.where(byz[:, None, None], yp, yb)

        # ---- Step 2: client local training (vmapped federation) ----
        updates = jax.vmap(client_update, in_axes=(None, 0, 0, None))(
            params, xb, yb, lr)
        U, unravel = agg.flatten_updates(updates)

        # ---- update-level attacks ----
        if acfg.kind in UPDATE_ATTACKS or acfg.kind == "backdoor":
            keys = jax.random.split(ka, C)
            U_att = jax.vmap(lambda u, k: attack_update(u, acfg.kind, k, acfg))(
                U, keys)
            U = jnp.where(byz[:, None], U_att, U)

        # ---- Steps 3-5: SecureServer (enclave guides -> registry) ----
        logs = {"byz": byz, "sel": sel}
        G = root = None
        if entry.needs_guides:
            guides = jax.vmap(guide_update_one, in_axes=(None, 0, 0, None))(
                params, guide_x, guide_y, lr)
            G, _ = agg.flatten_updates(guides)
        if entry.needs_root:
            root_tree = guide_update_one(params, fed.root_x, fed.root_y, lr)
            r, _ = agg.flatten_updates(
                jax.tree.map(lambda a: a[None], root_tree))
            root = r[0]
        ctx = AggregationContext(
            key=kr, f=cfg.f, dfl=cfg.dfl, byz_mask=byz, guides=G,
            root_update=root, resample_s=cfg.resample_s,
            use_kernel_stats=cfg.use_kernel_stats,
            use_kernel_agg=cfg.use_kernel_agg)
        delta, agg_logs = fed.server.aggregate(cfg.aggregator, U, ctx)
        logs.update(agg_logs)

        new_params = jax.tree.map(
            lambda p, d: p - d, params, unravel(delta))
        return new_params, logs

    return round_step


# ----------------------------------------------------------------------

def run_federated_training(model: SmallModel, fed: Federation, cfg: FLConfig,
                           lr_schedule: Callable, log_every: int = 0) -> Dict:
    key = jax.random.PRNGKey(cfg.seed)
    params = model.init(jax.random.PRNGKey(cfg.seed + 1))
    round_step = _build_round_step(model, fed, cfg)

    history = {"round": [], "acc": [], "mask_tpr": [], "mask_fpr": [],
               "c1c2": []}
    for i in range(1, cfg.rounds + 1):
        key, sub = jax.random.split(key)
        lr = float(lr_schedule(i))
        params, logs = round_step(params, sub, lr)
        if i % cfg.eval_every == 0 or i == cfg.rounds:
            acc = model.accuracy(params, fed.test_x, fed.test_y)
            history["round"].append(i)
            history["acc"].append(acc)
            byz = np.asarray(logs["byz"])
            if "mask" in logs:
                mask = np.asarray(logs["mask"])
                flagged = ~mask
                tpr = flagged[byz].mean() if byz.any() else 1.0
                fpr = flagged[~byz].mean() if (~byz).any() else 0.0
                history["mask_tpr"].append(float(tpr))
                history["mask_fpr"].append(float(fpr))
            if "c1c2" in logs:
                history["c1c2"].append(np.asarray(logs["c1c2"]))
            if log_every and i % log_every == 0:
                print(f"  round {i:5d} acc={acc:.4f}")
    history["final_acc"] = history["acc"][-1] if history["acc"] else float("nan")
    history["params"] = params
    return history
