"""Evaluation metrics: main-task accuracy and targeted-backdoor accuracy."""
from __future__ import annotations

import jax.numpy as jnp

from ..core.attacks import AttackConfig


def backdoor_accuracy(model, params, test_x, test_y, acfg: AttackConfig):
    """Fraction of trigger-stamped source-class inputs classified as the
    attacker's target class (lower = better defence)."""
    sel = test_y == acfg.source_class
    x = test_x[sel]
    if x.shape[0] == 0:
        return 0.0
    if x.ndim >= 3:
        x = x.at[:, :3, :3].set(1.0)
    else:
        x = x.at[:, :3].set(1.0)
    preds = jnp.argmax(model.apply(params, x), -1)
    return float((preds == acfg.target_class).mean())


def main_task_accuracy(model, params, test_x, test_y, acfg: AttackConfig):
    """Accuracy on all classes except the backdoor source class."""
    sel = test_y != acfg.source_class
    return model.accuracy(params, test_x[sel], test_y[sel])
