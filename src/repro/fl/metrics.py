"""Evaluation metrics — jittable, device-resident (DESIGN.md §7).

The seed metrics used dynamic-shape boolean indexing (``test_x[sel]``)
and ``float()`` casts, so every eval forced a host round-trip and could
never compile into the round engine's scan.  Every metric here is a
**where-masked reduction over a static-shape test set**:

  * selections are boolean masks, never gathers — shapes stay static, so
    the same function runs eagerly, under ``jax.jit``, or in the scan
    tail of :class:`~repro.fl.engine.RoundEngine`;
  * counts are integer sums (exact under any reduction association —
    what makes the in-scan eval bitwise-equal to the host-loop eval)
    with a single fp32 division at the end;
  * results are **device scalars** — nothing here syncs the host.

The trigger-stamped backdoor test set is precomputed once per
federation (:func:`make_backdoor_eval`, cached by
``Federation.backdoor_eval``) instead of re-stamping
``x.at[:, :3, :3].set(1.0)`` on every eval call; the loose
``backdoor_accuracy(model, params, test_x, test_y, acfg)`` signature is
kept for the fig-7 benchmark and stamps inline (still jittable).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..core.attacks import AttackConfig


def _ratio(num, den, empty):
    """Exact integer counts -> fp32 ratio; ``empty`` when ``den == 0``."""
    return jnp.where(den > 0,
                     num.astype(jnp.float32)
                     / jnp.maximum(den, 1).astype(jnp.float32),
                     jnp.float32(empty))


def masked_accuracy(model, params, x, y, mask=None):
    """Fraction of ``mask``-selected rows classified correctly.

    ``mask=None`` scores the whole set.  Correctness is counted with an
    integer sum, so the value is bitwise identical whether this runs
    eagerly, jitted, or inside a scan."""
    preds = jnp.argmax(model.apply(params, x), -1)
    hit = preds == y
    if mask is None:
        return _ratio(jnp.sum(hit), jnp.asarray(y.shape[0]), 0.0)
    keep = mask.astype(bool)
    return _ratio(jnp.sum(hit & keep), jnp.sum(keep), 0.0)


def accuracy(model, params, x, y):
    """Whole-test-set accuracy as a device scalar (jittable twin of
    ``SmallModel.accuracy``; same integer count, fp32 division)."""
    return masked_accuracy(model, params, x, y)


def mask_rates(mask, byz, valid=None):
    """Byzantine-detection TPR/FPR from a round's keep-mask.

    ``mask`` is the aggregator's keep decision (True = kept), ``byz`` the
    ground-truth Byzantine bits for the same client rows.  Flagged means
    *not* kept.  Degenerate cohorts keep the legacy conventions: TPR is
    1.0 with no Byzantine client, FPR 0.0 with no benign client.  Both
    come back as device scalars from exact integer counts.

    ``valid`` (async rounds, DESIGN.md §13) restricts the accounting to
    rows that actually participated — the live cohort plus landed stale
    updates: a Byzantine straggler is scored at its LANDING round, never
    silently dropped, and empty buffer slots/dropped-out clients count
    toward neither rate.  ``valid=None`` (every pre-async call) is the
    all-rows accounting, bit for bit."""
    flagged = ~mask.astype(bool)
    byz = byz.astype(bool)
    if valid is not None:
        v = valid.astype(bool)
        flagged = flagged & v
        tpr = _ratio(jnp.sum(flagged & byz), jnp.sum(byz & v), 1.0)
        fpr = _ratio(jnp.sum(flagged & ~byz), jnp.sum(~byz & v), 0.0)
        return tpr, fpr
    tpr = _ratio(jnp.sum(flagged & byz), jnp.sum(byz), 1.0)
    fpr = _ratio(jnp.sum(flagged & ~byz), jnp.sum(~byz), 0.0)
    return tpr, fpr


# ----------------------------------------------------------------------
# Backdoor eval set — stamped once, reused every eval
# ----------------------------------------------------------------------

def stamp_trigger(x):
    """Apply the paper's pixel-pattern trigger to a batch (3x3 top-left
    patch for image inputs, first 3 features for flat inputs)."""
    if x.ndim >= 3:
        return x.at[:, :3, :3].set(1.0)
    return x.at[:, :3].set(1.0)


@dataclasses.dataclass(frozen=True)
class BackdoorEval:
    """The precomputed backdoor evaluation set for one federation.

    ``x`` is the full test set with the trigger stamped on *every* row;
    ``src`` masks the rows whose true label is the attack's source class
    — the only rows the backdoor metric scores.  Keeping the full
    (static) shape plus a mask is what lets the metric compile: the
    seed's ``test_x[test_y == src]`` gather had a data-dependent shape.
    """
    x: jnp.ndarray
    src: jnp.ndarray
    source_class: int
    target_class: int


def make_backdoor_eval(test_x, test_y, acfg: AttackConfig) -> BackdoorEval:
    """Stamp the trigger once; every later eval is a masked reduction."""
    return BackdoorEval(x=stamp_trigger(test_x),
                        src=test_y == acfg.source_class,
                        source_class=acfg.source_class,
                        target_class=acfg.target_class)


def backdoor_accuracy_on(model, params, ev: BackdoorEval):
    """Fraction of trigger-stamped source-class inputs classified as the
    attacker's target class (lower = better defence); device scalar."""
    preds = jnp.argmax(model.apply(params, ev.x), -1)
    return _ratio(jnp.sum((preds == ev.target_class) & ev.src),
                  jnp.sum(ev.src), 0.0)


def backdoor_accuracy(model, params, test_x, test_y, acfg: AttackConfig):
    """One-shot form (stamps inline, jittable).  Prefer
    ``Federation.backdoor_eval`` + :func:`backdoor_accuracy_on` on any
    path that evaluates more than once."""
    return backdoor_accuracy_on(model, params,
                                make_backdoor_eval(test_x, test_y, acfg))


def main_task_accuracy(model, params, test_x, test_y, acfg: AttackConfig):
    """Accuracy on all classes except the backdoor source class."""
    return masked_accuracy(model, params, test_x, test_y,
                           test_y != acfg.source_class)


# ----------------------------------------------------------------------
# Communication cost — a first-class, recorded quantity
# ----------------------------------------------------------------------

def comm_stats(cfg, d: int, model_shards: int = 1):
    """Per-round wire traffic of one federated round, in bytes.

    ``d`` is the flattened model dimension.  Uplink is what the
    ``cfg.n_selected`` participating clients send — the codec's encoded
    wire size per client (``fl/compression.wire_bytes``: payload plus
    any scale sidecar), NOT the dense f32 size; downlink is the server
    broadcasting the f32 model to the same clients (the paper's server
    sends plain parameters — only the client→server direction is
    compressed).  Keys are flat host ints/floats so run histories stay
    elementwise-comparable across the solo and sweep paths
    (tests/test_sweep.py compares every history key by value).

    ``model_shards`` (> 1 on a tensor-sharded mesh —
    sharding.model_shard_count) prices the wire format each model shard
    actually emits: every shard encodes its **local D/model_shards
    slice independently** (per-shard qblock padding and scale sidecar
    included), and the per-client cost is the sum over shards.  This is
    the whole satellite contract: the stats are pure host arithmetic on
    metadata — ``d`` comes from aval sizes, never from a device gather
    of the sharded params — so a 100M-param sharded run prices its
    uplink without a single extra host sync.  ``model_shards=1``
    (every existing call) is bit-for-bit the old arithmetic."""
    from .compression import get_codec, wire_bytes
    codec = get_codec(getattr(cfg, "compression", "f32"))
    c = cfg.n_selected
    if model_shards > 1:
        base, extra = divmod(d, model_shards)
        # uneven split: `extra` shards hold one more element (how XLA
        # tiles a non-dividing dim is degrade-to-replicated in our
        # constraints, but the priced contract is the even-ish split)
        per_client = ((model_shards - extra) * wire_bytes(codec, base)
                      + extra * wire_bytes(codec, base + 1))
    else:
        per_client = wire_bytes(codec, d)
    dense = d * 4
    return {
        "uplink_bytes_per_client": int(per_client),
        "uplink_bytes_per_round": int(c * per_client),
        "downlink_bytes_per_round": int(c * dense),
        "dense_uplink_bytes_per_round": int(c * dense),
        "uplink_reduction": float(dense / per_client),
    }


def round_telemetry_bytes(cfg) -> int:
    """On-device bytes one round's telemetry block adds to the scan's
    stacked ys — the §11 memory model, as code.

    The block is *summaries, not vectors*: counts (kept/tagged and, for
    DiverseFL, C1/C2 pass counts — int32) plus mean/max norm scalars
    (f32), all reduced from the per-client logs inside the scan.  So the
    per-round cost is O(#fields)·4 bytes — **independent of N** — and a
    whole R-round run's drained block is ``R · round_telemetry_bytes``
    riding the one host sync.  Mirrors the key logic of
    ``fl/telemetry.make_round_telemetry_fn`` field for field (the unit
    test pins the two against each other)."""
    fields = 0
    entry = None
    try:
        from .server import get_aggregator
        entry = get_aggregator(cfg.aggregator)
    except ValueError:
        pass
    # "mask" is logged by every masked rule (diversefl/oracle) -> kept +
    # tagged; the DiverseFL criterion adds c1/c2 pass counts and the
    # z_sq/g_sq norm mean/max pairs
    if cfg.aggregator in ("oracle",) or (entry is not None
                                         and entry.needs_guides):
        fields += 2                           # kept, tagged (int32)
    if entry is not None and entry.needs_guides:
        fields += 2                           # c1_pass, c2_pass (int32)
        fields += 4                           # upd/guide norm mean+max (f32)
    # streaming fold's non-finite guard (active on the raw-f32 stream —
    # lossy codecs skip it) logs a per-client bit the block popcounts
    from .compression import get_codec
    from .streaming import get_streaming
    if (getattr(cfg, "streaming", False)
            and get_streaming(cfg.aggregator) is not None
            and get_codec(getattr(cfg, "compression", "f32")).lossless):
        fields += 1                           # nonfinite (int32)
    # async rounds: cohort size + the three staleness decision counts
    if getattr(cfg, "async_rounds", False):
        fields += 4                           # cohort, stale_* (int32)
    return fields * 4


# ----------------------------------------------------------------------
# The round engine's eval tail
# ----------------------------------------------------------------------

def make_eval_fn(model, fed, cfg):
    """Build ``eval_fn(params, logs) -> {metric: device array}`` — the
    one eval definition every execution mode shares.

    The host-loop path jits it and calls it once per segment; the
    one-dispatch path traces the *same function* into the scan tail of
    ``RoundEngine.run_training``, which is why the two paths agree
    bitwise (integer-count metrics are association-free).  The metric
    set is static per config: main-task + backdoor accuracy appear under
    a backdoor attack, detection TPR/FPR and the C1·C2 criterion logs
    whenever the aggregator emits a keep-mask.
    """
    acfg = cfg.attack
    bd = fed.backdoor_eval(acfg) if acfg.kind == "backdoor" else None
    main_mask = None if bd is None else ~bd.src

    def eval_fn(params, logs):
        m = {"acc": accuracy(model, params, fed.test_x, fed.test_y)}
        if bd is not None:
            m["main_acc"] = masked_accuracy(model, params, fed.test_x,
                                            fed.test_y, main_mask)
            m["backdoor_acc"] = backdoor_accuracy_on(model, params, bd)
        if "mask" in logs:
            m["mask_tpr"], m["mask_fpr"] = mask_rates(logs["mask"],
                                                      logs["byz"],
                                                      logs.get("cand"))
        if "c1c2" in logs:
            m["c1c2"] = logs["c1c2"]
        return m

    return eval_fn
