"""Paper-scale models (Sec. IV): softmax regression, the 3-layer MLP
("3-NN", 200-200 hidden), the Appendix-C small CNN and VGG-11 with group
norm.  Pure-functional: init(key) -> params, apply(params, x) -> logits.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SmallModel:
    name: str
    init: Callable
    apply: Callable                    # (params, x) -> logits
    input_shape: tuple
    n_classes: int

    def loss(self, params, x, y, l2: float = 0.0):
        logits = self.apply(params, x)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
        if l2:
            nll = nll + 0.5 * l2 * sum(
                jnp.vdot(p, p) for p in jax.tree.leaves(params))
        return nll

    def accuracy(self, params, x, y, batch: int = 2048):
        correct = 0
        n = y.shape[0]
        for i in range(0, n, batch):
            lg = self.apply(params, x[i:i + batch])
            correct += int((jnp.argmax(lg, -1) == y[i:i + batch]).sum())
        return correct / n


def _glorot(key, shape):
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    fan_out = shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def _glorot_conv(key, shape):  # (kh, kw, cin, cout)
    rf = shape[0] * shape[1]
    lim = jnp.sqrt(6.0 / (rf * shape[2] + rf * shape[3]))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


# ----------------------------------------------------------------------

def softmax_regression(input_dim: int = 784, n_classes: int = 10,
                       zero_init: bool = True):
    def init(key):
        w = jnp.zeros((input_dim, n_classes)) if zero_init else \
            _glorot(key, (input_dim, n_classes))
        return {"w": w, "b": jnp.zeros((n_classes,))}

    def apply(params, x):
        return x.reshape(x.shape[0], -1) @ params["w"] + params["b"]
    return SmallModel("softmax_regression", init, apply,
                      (input_dim,), n_classes)


def mlp3(input_dim: int = 784, n_classes: int = 10, hidden: int = 200):
    """The paper's 3-NN: two hidden layers of 200 neurons."""
    def init(key):
        ks = jax.random.split(key, 3)
        return {"w1": _glorot(ks[0], (input_dim, hidden)), "b1": jnp.zeros((hidden,)),
                "w2": _glorot(ks[1], (hidden, hidden)), "b2": jnp.zeros((hidden,)),
                "w3": _glorot(ks[2], (hidden, n_classes)), "b3": jnp.zeros((n_classes,))}

    def apply(p, x):
        h = jax.nn.relu(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        h = jax.nn.relu(h @ p["w2"] + p["b2"])
        return h @ p["w3"] + p["b3"]
    return SmallModel("mlp3", init, apply, (input_dim,), n_classes)


# ----------------------------------------------------------------------

def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _maxpool(x, k, s):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID")


def _group_norm(x, scale, bias, groups):
    n, h, w, c = x.shape
    g = x.reshape(n, h, w, groups, c // groups)
    mu = g.mean((1, 2, 4), keepdims=True)
    var = g.var((1, 2, 4), keepdims=True)
    g = (g - mu) * jax.lax.rsqrt(var + 1e-5)
    return g.reshape(n, h, w, c) * scale + bias


def small_cnn(n_classes: int = 10):
    """Appendix C table V: conv 3->16 (3x3, pad1) + relu + maxpool3s3,
    conv 16->64 (4x4, valid) + relu + maxpool4s4, fc 64-384-192-C."""
    def init(key):
        ks = jax.random.split(key, 5)
        return {"c1": _glorot_conv(ks[0], (3, 3, 3, 16)), "cb1": jnp.zeros((16,)),
                "c2": _glorot_conv(ks[1], (4, 4, 16, 64)), "cb2": jnp.zeros((64,)),
                "w1": _glorot(ks[2], (64, 384)), "b1": jnp.zeros((384,)),
                "w2": _glorot(ks[3], (384, 192)), "b2": jnp.zeros((192,)),
                "w3": _glorot(ks[4], (192, n_classes)), "b3": jnp.zeros((n_classes,))}

    def apply(p, x):
        h = jax.nn.relu(_conv(x, p["c1"]) + p["cb1"])
        h = _maxpool(h, 3, 3)
        h = jax.nn.relu(_conv(h, p["c2"], padding="VALID") + p["cb2"])
        h = _maxpool(h, 4, 4)
        h = h.reshape(h.shape[0], -1)[:, :64]
        h = jax.nn.relu(h @ p["w1"] + p["b1"])
        h = jax.nn.relu(h @ p["w2"] + p["b2"])
        return h @ p["w3"] + p["b3"]
    return SmallModel("small_cnn", init, apply, (32, 32, 3), n_classes)


def vgg11(n_classes: int = 10, gn_group_channels: int = 16):
    """Table I VGG-11 with group norm (16 channels/group), avg-pool head.
    Dropout is omitted (deterministic eval path; noted in EXPERIMENTS.md)."""
    chans = [(3, 64), (64, 128), (128, 256), (256, 256),
             (256, 512), (512, 512), (512, 512), (512, 512)]
    pool_after = {0, 1, 3, 7}           # keep spatial dims manageable at 32x32

    def init(key):
        ks = jax.random.split(key, len(chans) + 3)
        p = {}
        for i, (ci, co) in enumerate(chans):
            p[f"c{i}"] = _glorot_conv(ks[i], (3, 3, ci, co))
            p[f"gs{i}"] = jnp.ones((co,))
            p[f"gb{i}"] = jnp.zeros((co,))
        p["w1"] = _glorot(ks[-3], (512, 4096)); p["b1"] = jnp.zeros((4096,))
        p["w2"] = _glorot(ks[-2], (4096, 4096)); p["b2"] = jnp.zeros((4096,))
        p["w3"] = _glorot(ks[-1], (4096, n_classes)); p["b3"] = jnp.zeros((n_classes,))
        return p

    def apply(p, x):
        h = x
        for i, (ci, co) in enumerate(chans):
            h = _conv(h, p[f"c{i}"])
            h = _group_norm(h, p[f"gs{i}"], p[f"gb{i}"], co // gn_group_channels)
            h = jax.nn.relu(h)
            if i in pool_after:
                h = _maxpool(h, 2, 2)
        h = h.mean(axis=(1, 2))          # adaptive avg pool to 1x1
        h = jax.nn.relu(h @ p["w1"] + p["b1"])
        h = jax.nn.relu(h @ p["w2"] + p["b2"])
        return h @ p["w3"] + p["b3"]
    return SmallModel("vgg11", init, apply, (32, 32, 3), n_classes)
