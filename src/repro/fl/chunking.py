"""Chunked client mapping — vmap semantics at O(chunk) memory.

``chunked_vmap`` is the one primitive the round engine and the
SecureServer share for bounding the client axis: with ``chunk=None`` (or
``chunk >= C``) it is *exactly* ``jax.vmap`` — the same traced graph,
bit-for-bit with the unchunked path — and otherwise the leading client
axis is padded to a multiple of ``chunk``, reshaped to ``(k, chunk,
...)`` blocks and swept sequentially with ``jax.lax.map`` (vmap inside
each block), so peak working memory is O(chunk x per-client footprint)
instead of O(C x per-client footprint).

The padding/blocking scheme is factored out (``pad_to_blocks`` /
``unblock`` / ``block_valid``) because the streaming-aggregation
subsystem (fl/streaming.py) sweeps the *same* blocks with a
``jax.lax.scan`` that folds each block into a constant-size AggState
instead of stacking outputs — one partition definition keeps the two
sweeps row-aligned, which the bitwise streaming == dense contract
depends on.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import ShardMismatchError


def pad_to_blocks(args, chunk: int) -> Tuple[tuple, int, int]:
    """Pad the shared leading axis C of every array in the ``args`` pytree
    to a multiple of ``chunk`` (with copies of the first rows) and reshape
    each leaf to ``(k, chunk, ...)`` blocks.  Returns ``(blocks, k, C)``.
    Padding rows carry no meaning — consumers must discard their outputs
    (``unblock``) or zero their contributions (``block_valid``)."""
    leaves = jax.tree.leaves(args)
    if not leaves:
        raise ValueError("pad_to_blocks needs at least one array argument")
    C = leaves[0].shape[0]
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    if chunk > C:
        # x[:pad] cannot supply more than C padding rows; callers clamp
        # (chunked_vmap is plain vmap and stream_aggregate folds a single
        # C-sized block when chunk >= C) — fail loudly for new consumers
        raise ValueError(
            f"chunk ({chunk}) exceeds the leading axis ({C}); take the "
            f"vmap / single-block path for chunk >= C")
    k = -(-C // chunk)                       # ceil(C / chunk) blocks
    pad = k * chunk - C

    def to_blocks(x):
        if pad:
            x = jnp.concatenate([x, x[:pad]], axis=0)
        return x.reshape((k, chunk) + x.shape[1:])

    return jax.tree.map(to_blocks, args), k, C


def unblock(out, k: int, chunk: int, C: int):
    """Inverse of ``pad_to_blocks`` on outputs: (k, chunk, ...) blocks ->
    (C, ...) with the padding rows dropped."""
    return jax.tree.map(
        lambda x: x.reshape((k * chunk,) + x.shape[2:])[:C], out)


def block_valid(k: int, chunk: int, C: int) -> jnp.ndarray:
    """(k, chunk) bool mask: True where a block row is a real client,
    False on the padding rows of the final block."""
    return (jnp.arange(k * chunk) < C).reshape(k, chunk)


def resolve_shards(shards: int, k: int) -> int:
    """Clamp a requested shard count to the largest divisor of ``k``
    (the block count) not exceeding it — contiguous groups must tile the
    block axis exactly, and a non-divisible request degrades gracefully
    instead of failing inside a trace."""
    s = max(1, min(int(shards), k))
    while k % s:
        s -= 1
    return s


def group_blocks(blocks, k: int, shards: int):
    """Reshape ``(k, chunk, ...)`` blocks into ``(shards, k // shards,
    chunk, ...)`` contiguous shard groups — shard ``j`` owns blocks
    ``[j*k/S, (j+1)*k/S)``, i.e. a contiguous client range, which is
    what keeps each shard's left fold row-aligned with the sequential
    sweep (fl/streaming.py's canonical merge-order contract)."""
    if k % shards:
        raise ShardMismatchError(
            f"shards ({shards}) must divide the block count ({k}); "
            f"use resolve_shards")
    return jax.tree.map(
        lambda x: x.reshape((shards, k // shards) + x.shape[1:]), blocks)


def resolve_pods(pods: Optional[int], k: int, auto: int = 1) -> int:
    """The pod count the two-tier fold actually uses.

    ``pods=None`` derives from ``auto`` (the mesh's pod-axis size),
    clamped to the largest divisor of the block count ``k`` — a mesh
    shape can never break an off-mesh-equivalent run.  An **explicit**
    ``pods`` is a contract, not a hint: a value that does not divide
    ``k`` raises the named :class:`~repro.sharding.ShardMismatchError`
    (before this error class, the mismatch surfaced as a reshape
    failure deep inside the traced fold)."""
    if pods is None:
        return resolve_shards(auto, k)
    p = int(pods)
    if p < 1:
        raise ShardMismatchError(f"pods must be >= 1, got {p}")
    if p > k or k % p:
        raise ShardMismatchError(
            f"pods ({p}) must divide the padded block count ({k}); pick a "
            f"client_chunk so ceil(C / chunk) tiles the pods, or pass "
            f"pods=None to clamp to the mesh-derived divisor")
    return p


def group_blocks_2d(blocks, k: int, pods: int, shards: int):
    """Two-level grouping for the hierarchical fold (fl/streaming.py,
    DESIGN.md §9): ``(k, chunk, ...)`` blocks -> ``(pods, shards,
    k / (pods·shards), chunk, ...)``.

    Pod ``p`` owns the contiguous block range ``[p·k/P, (p+1)·k/P)``
    (pod-major — the same contiguous client ranges the ``("pod",
    "data")`` client sharding places on pod ``p``'s devices), and
    within a pod shard ``s`` owns a contiguous sub-range — so every
    ``(p, s)`` lane's left fold is row-aligned with the sequential
    sweep, and flattening the first two axes recovers ``group_blocks``
    with ``pods·shards`` flat groups."""
    if k % pods:
        raise ShardMismatchError(
            f"pods ({pods}) must divide the block count ({k}); "
            f"use resolve_pods")
    if (k // pods) % shards:
        raise ShardMismatchError(
            f"per-pod shards ({shards}) must divide the per-pod block "
            f"count ({k // pods}); use resolve_shards")
    return jax.tree.map(
        lambda x: x.reshape(
            (pods, shards, k // (pods * shards)) + x.shape[1:]), blocks)


def chunked_vmap(fn, args: tuple, chunk: Optional[int] = None):
    """Map ``fn`` over the shared leading axis of every array in ``args``.

    ``args`` is a tuple of pytrees whose leaves all carry the same leading
    dimension C (the client axis).  Returns exactly what
    ``jax.vmap(fn)(*args)`` returns; ``chunk`` only bounds how much of the
    axis is in flight at once.  Padding rows (copies of the first rows)
    are computed and discarded — they never reach the output.
    """
    leaves = jax.tree.leaves(args)
    if not leaves:
        raise ValueError("chunked_vmap needs at least one array argument")
    C = leaves[0].shape[0]
    if chunk is None or chunk >= C:
        return jax.vmap(fn)(*args)
    blocks, k, C = pad_to_blocks(args, chunk)
    out = jax.lax.map(lambda a: jax.vmap(fn)(*a), blocks)
    return unblock(out, k, chunk, C)
