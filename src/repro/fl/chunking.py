"""Chunked client mapping — vmap semantics at O(chunk) memory.

``chunked_vmap`` is the one primitive the round engine and the
SecureServer share for bounding the client axis: with ``chunk=None`` (or
``chunk >= C``) it is *exactly* ``jax.vmap`` — the same traced graph,
bit-for-bit with the unchunked path — and otherwise the leading client
axis is padded to a multiple of ``chunk``, reshaped to ``(k, chunk,
...)`` blocks and swept sequentially with ``jax.lax.map`` (vmap inside
each block), so peak working memory is O(chunk x per-client footprint)
instead of O(C x per-client footprint).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def chunked_vmap(fn, args: tuple, chunk: Optional[int] = None):
    """Map ``fn`` over the shared leading axis of every array in ``args``.

    ``args`` is a tuple of pytrees whose leaves all carry the same leading
    dimension C (the client axis).  Returns exactly what
    ``jax.vmap(fn)(*args)`` returns; ``chunk`` only bounds how much of the
    axis is in flight at once.  Padding rows (copies of the first rows)
    are computed and discarded — they never reach the output.
    """
    leaves = jax.tree.leaves(args)
    if not leaves:
        raise ValueError("chunked_vmap needs at least one array argument")
    C = leaves[0].shape[0]
    if chunk is None or chunk >= C:
        return jax.vmap(fn)(*args)
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    k = -(-C // chunk)                       # ceil(C / chunk) blocks
    pad = k * chunk - C

    def to_blocks(x):
        if pad:
            x = jnp.concatenate([x, x[:pad]], axis=0)
        return x.reshape((k, chunk) + x.shape[1:])

    blocks = jax.tree.map(to_blocks, args)
    out = jax.lax.map(lambda a: jax.vmap(fn)(*a), blocks)
    return jax.tree.map(
        lambda x: x.reshape((k * chunk,) + x.shape[2:])[:C], out)
