"""Update-stream codecs — quantized client→server traffic (DESIGN.md §10).

At the scale the two-tier engine unlocked, the bottleneck of a federated
round is no longer FLOPs but the (N, D) client→server update traffic the
secure aggregation must ingest — paid once on the wire and once crossing
the enclave boundary.  This module makes the wire format explicit:

  * **Codec registry** — a :class:`Codec` maps a flat f32 update row
    (``(..., D)``, last axis = parameters) to its encoded wire form (a
    pytree of arrays) and back.  Registered codecs:

      - ``f32``  — passthrough.  Lossless: ``decode(encode(x))`` is the
        identity *in the jaxpr*, so every f32 path is bitwise-equal to
        the uncompressed fold by construction (the documented contract —
        callers skip the error-feedback state entirely).
      - ``bf16`` — round-to-nearest-even bf16 payload (2 bytes/param).
        bf16→f32 is exact, so the only error is the encode rounding:
        |x − dec(enc(x))| ≤ 2⁻⁸·|x| (half a bf16 ULP).
      - ``int8`` — symmetric per-block quantization (1 byte/param +
        one f32 scale per ``QBLOCK`` params): each ``QBLOCK``-wide block
        of the last axis stores ``q = round(x / scale)`` with
        ``scale = absmax/127``, so |x − dec(enc(x))| ≤ scale/2 =
        absmax_block/254 per block.

  * **Error feedback** — lossy codecs carry a per-client residual: the
    client transmits ``enc(u + resid)`` and keeps
    ``resid' = (u + resid) − dec(enc(u + resid))``, so quantization
    error is fed back into the *next* round's update instead of lost
    (the standard EF-SGD construction; what keeps bf16/int8 training
    within a point of uncompressed).  The residual lives in the round
    engine's scan carry (fl/engine.py) — O(N·D) state, the memory price
    of remembering per-client error.

  * **Decoding is the shared reference decoder** — ``int8`` decode
    routes through ``kernels/ref.dequant_int8_ref``, the same oracle the
    fused Pallas dequantize-and-fold kernel
    (kernels/dequant_fold.py) is tested against, so the dense fallback
    rules and the streaming kernel fold dequantize identical bits.

Encoded form: ``{"q": payload}`` for dense-payload codecs (f32/bf16 —
``Codec.wire_dtype`` names the payload dtype) and
``{"q": int8, "scale": f32}`` for int8 (``Codec.qblock`` set).  The
streaming fold keys its kernel dispatch off these two attributes
(fl/streaming.weighted_mean_rule).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ref import dequant_int8_ref

QBLOCK = 128   # int8 quantization block width (params per f32 scale)


@dataclasses.dataclass(frozen=True)
class Codec:
    """One wire format for flat update rows.

    ``encode(x)`` maps ``(..., D)`` f32 to the encoded pytree;
    ``decode(enc)`` inverts it to ``(..., D)`` f32.  ``lossless`` means
    decode∘encode is the bitwise identity (f32 only — such codecs skip
    the error-feedback state entirely, which is what makes the f32 path
    structurally identical to the uncompressed fold).  ``wire_dtype``
    names the dtype of ``enc["q"]`` when the payload is directly
    foldable by the masked-agg kernel (its in-kernel f32 cast *is* the
    dequantization); ``qblock`` is set for per-block-scaled codecs that
    need the fused dequantize-and-fold kernel instead."""
    name: str
    lossless: bool
    encode: Callable[[jnp.ndarray], Dict[str, jnp.ndarray]]
    decode: Callable[[Dict[str, jnp.ndarray]], jnp.ndarray]
    wire_dtype: Optional[Any] = None
    qblock: Optional[int] = None


_CODECS: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    if codec.name in _CODECS:
        raise ValueError(f"codec {codec.name!r} already registered")
    _CODECS[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    try:
        return _CODECS[name]
    except KeyError:
        raise ValueError(f"unknown compression codec {name!r}; "
                         f"available: {available_codecs()}") from None


def available_codecs() -> Tuple[str, ...]:
    """Registered codec names, in registration order."""
    return tuple(_CODECS)


# ----------------------------------------------------------------------
# Registered codecs
# ----------------------------------------------------------------------

def _f32_encode(x):
    return {"q": x.astype(jnp.float32)}


def _f32_decode(enc):
    return enc["q"]


def _bf16_encode(x):
    return {"q": x.astype(jnp.bfloat16)}


def _bf16_decode(enc):
    return enc["q"].astype(jnp.float32)


def _int8_encode(x, qblock: int = QBLOCK):
    """Symmetric per-block int8: q = round(x/scale), scale = absmax/127.

    The last axis is padded to a ``qblock`` multiple (padding zeros
    cannot change a block's absmax), quantized blockwise, and sliced
    back — ``q`` keeps the input's (..., D) shape, ``scale`` is
    (..., ceil(D/qblock)).  An all-zero block gets scale 0 and q 0
    (the divisor is clamped away from 0), decoding exactly to 0."""
    x = x.astype(jnp.float32)
    d = x.shape[-1]
    nb = -(-d // qblock)
    pad = nb * qblock - d
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    xb = xp.reshape(xp.shape[:-1] + (nb, qblock))
    scale = jnp.max(jnp.abs(xb), axis=-1) / jnp.float32(127.0)
    q = jnp.round(xb / jnp.maximum(scale, jnp.float32(1e-30))[..., None])
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    q = q.reshape(xp.shape)[..., :d]
    return {"q": q, "scale": scale}


def _int8_decode(enc, qblock: int = QBLOCK):
    return dequant_int8_ref(enc["q"], enc["scale"], qblock)


F32 = register_codec(Codec("f32", lossless=True, encode=_f32_encode,
                           decode=_f32_decode, wire_dtype=jnp.float32))
BF16 = register_codec(Codec("bf16", lossless=False, encode=_bf16_encode,
                            decode=_bf16_decode, wire_dtype=jnp.bfloat16))
INT8 = register_codec(Codec("int8", lossless=False, encode=_int8_encode,
                            decode=_int8_decode, qblock=QBLOCK))


# ----------------------------------------------------------------------
# Error feedback + guide-side quantization
# ----------------------------------------------------------------------

def encode_with_feedback(codec: Codec, u, resid):
    """The client boundary: transmit ``enc(u + resid)``, keep the error.

    Returns ``(enc, dec, new_resid)`` where ``dec`` is what the server
    folds (``decode(enc)``) and ``new_resid = (u + resid) − dec`` is the
    compression error carried into the next round (EF-SGD).  Both sides
    of the wire are derived from the same ``enc`` bits, so server-side
    aggregation and client-side residual accounting can never drift."""
    v = u.astype(jnp.float32) + resid
    enc = codec.encode(v)
    dec = codec.decode(enc)
    return enc, dec, v - dec


def quantize_tree(codec: Codec, tree):
    """Per-tensor quantize-dequantize roundtrip over a stacked pytree.

    Used for the enclave's guiding updates (SecureServer.compute_guides):
    each leaf is (C, *param_shape); the non-client dims flatten so the
    codec's last-axis blocks apply per tensor, then the decoded f32
    values reshape back.  Guides carry **no** error feedback — they are
    recomputed inside the enclave from the same sealed samples every
    round, so there is no per-round error to carry."""
    if codec.lossless:
        return tree

    def qdq(leaf):
        flat = leaf.reshape((leaf.shape[0], -1))
        return codec.decode(codec.encode(flat)).reshape(leaf.shape)

    return jax.tree.map(qdq, tree)


def wire_bytes(codec: Codec, d: int) -> int:
    """Measured wire size of one client's encoded (d,) update: the sum
    of the encoded leaves' nbytes, from ``jax.eval_shape`` (shape-level
    — nothing materializes).  This is the number fl/metrics.comm_stats
    reports, so the comm metric tracks the actual encoded buffers, not
    a hand-maintained formula."""
    enc = jax.eval_shape(codec.encode,
                         jax.ShapeDtypeStruct((d,), jnp.float32))
    return sum(int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(enc))
