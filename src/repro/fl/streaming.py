"""Streaming aggregation — constant-memory partial aggregation (DESIGN.md §6).

After the scan/chunk/shard engine (PR 2), the dense ``(N, D)`` update
matrix (plus its ``(N, D)`` guide twin) was the last O(N) memory term in
a federated round — exactly the term that caps how many clients an
enclave-faithful simulation fits, since TEE memory is the scarce
resource the paper's server lives inside.  This module removes it for
every *associative* aggregation rule:

  * **AggState monoid** — each streaming rule is a
    :class:`StreamingAggregator` with

        init(d)                -> state            (the identity)
        update(state, u_i, ctx_i) -> (state, logs_i)
        merge(a, b)            -> state             (associative)
        finalize(state)        -> (delta, logs)

    ``state`` is a fixed-size pytree — O(D), never O(N·D).  ``update``
    folds ONE client's flattened update ``u_i`` (with its per-client
    context: guide row, Byzantine bit, validity) into the state;
    ``merge`` combines partial states from disjoint client sets (the
    cross-chunk / cross-shard / multi-pod combiner); ``finalize`` turns
    the state into the round delta.  ``update(s, u, c)`` must equal
    ``merge(s, update(init, u, c))`` up to fp rounding — that is the
    associativity contract tests/test_streaming.py property-checks.
  * **Registry alongside the AggregatorRegistry** — streaming rules are
    registered by decorator under the *same* names as fl/server.py's
    dense rules (registering a name the dense registry does not know is
    an error, so the two registries cannot drift).  ``mean``, ``oracle``,
    ``diversefl`` and ``fltrust`` stream — they are all weighted means
    with per-client weights, the DiverseFL C1/C2 criterion being
    *per-client* against the guiding update, so it streams exactly.
    ``median``/``trimmed_mean``/``krum``/``bulyan``/``resampling`` are
    not associative (``NON_STREAMING`` records why) and fall back to the
    dense path with an explicitly logged reason.
  * **The sweep** — ``stream_aggregate`` drives the fold over the same
    padded ``(k, chunk, ...)`` blocks ``chunked_vmap`` uses
    (fl/chunking.pad_to_blocks — one partition definition), but with a
    ``lax.scan`` carrying the AggState: each block's client updates are
    computed, folded, and *freed* before the next block starts, so a
    round peaks at O(chunk·D) instead of O(N·D).

**Bitwise contract.**  The default fold applies ``update`` row by row —
a strict left fold in client order, the exact association
``core.diversefl.masked_sum_fold`` fixes for the dense rules — so
streaming and dense paths agree *bit for bit* (delta and criterion
logs) for the masked-mean family, at any chunk size, with any
participation.  Padding rows contribute exact ±0.0 (weight 0) and a
trailing ``x + 0.0`` cannot change a float's magnitude.  With
``use_kernel_agg`` the fold instead accumulates per *block* through the
streaming Pallas kernel (kernels/masked_agg.masked_agg_update_kernel) —
one HBM pass per block into a donated (D,) accumulator; block-level
association trades the bitwise guarantee for fp-tolerance parity.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.diversefl import criterion_logs, diversefl_mask
from ..sharding import (data_shard_count, model_shard_count,
                        pod_data_counts, shard_clients,
                        shard_flat, shard_lanes)
from .chunking import (block_valid, group_blocks, group_blocks_2d,
                       pad_to_blocks, resolve_pods, resolve_shards, unblock)
from .server import _REGISTRY as _DENSE_REGISTRY
from .server import AggregationContext

logger = logging.getLogger(__name__)

AggState = Any          # fixed-size pytree of arrays — O(D), never O(N·D)
ClientCtx = Dict[str, jnp.ndarray]   # per-client arrays: guide/byz/valid


@dataclasses.dataclass(frozen=True)
class StreamingAggregator:
    """A bound streaming rule: an AggState monoid over client updates.

    ``weights``/``update_block`` are optional vectorized forms for the
    weighted-mean family: ``weights(U_blk, ctx_blk)`` maps a whole
    (c, D) block to per-client (numerator coeff, denominator coeff,
    logs); ``update_block`` folds a block in one step (through the
    streaming Pallas kernel when the rule was bound with
    ``use_kernel_agg``).  ``unroll`` is the sweep's row-fold unroll
    factor: 8 (matching ``masked_sum_fold``) is only layout-stable for
    rules whose weights are exact 0/1 — real-weight rules (fltrust)
    set 1, keeping the fold body a single mul + add that XLA lowers
    identically solo and vmapped (no FMA latitude, DESIGN.md §8)."""
    init: Callable[[int], AggState]
    update: Callable[[AggState, jnp.ndarray, ClientCtx],
                     Tuple[AggState, Dict]]
    merge: Callable[[AggState, AggState], AggState]
    finalize: Callable[[AggState], Tuple[jnp.ndarray, Dict]]
    weights: Optional[Callable] = None
    update_block: Optional[Callable] = None
    unroll: int = 8


@dataclasses.dataclass(frozen=True)
class StreamingEntry:
    """Registry row: ``bind(ctx)`` closes a rule over the round's static
    context (DiverseFL thresholds, root update, kernel flags) and returns
    the pure monoid."""
    name: str
    bind: Callable[[AggregationContext], StreamingAggregator]


_STREAMING: Dict[str, StreamingEntry] = {}

# Why each dense-only rule cannot fold into an O(D) state: the logged
# fallback reason when FLConfig.streaming=True requests one of these.
NON_STREAMING: Dict[str, str] = {
    "median": "coordinate-wise median needs every client's value per "
              "dimension — order statistics do not form a bounded monoid",
    "trimmed_mean": "per-dimension trimming needs the full sorted column "
                    "of client values",
    "krum": "Krum scores couple every pair of clients (pairwise "
            "distances), so no per-client fold exists",
    "bulyan": "recursive Krum selection couples every pair of clients",
    "resampling": "resampled groups average arbitrary client subsets "
                  "before the median — group membership is not a fold",
}


def register_streaming(name: str):
    """Decorator: register ``bind(ctx) -> StreamingAggregator`` under a
    name the dense AggregatorRegistry already knows."""
    def deco(bind_fn):
        if name in _STREAMING:
            raise ValueError(f"streaming rule {name!r} already registered")
        if name not in _DENSE_REGISTRY:
            raise ValueError(
                f"streaming rule {name!r} has no dense AggregatorRegistry "
                f"counterpart — register the dense rule first so the two "
                f"registries cannot drift")
        _STREAMING[name] = StreamingEntry(name, bind_fn)
        return bind_fn
    return deco


def get_streaming(name: str) -> Optional[StreamingEntry]:
    """The streaming entry for ``name``, or None if the rule only exists
    densely (callers fall back with ``fallback_reason``)."""
    return _STREAMING.get(name)


def streaming_rules() -> Tuple[str, ...]:
    """Registered streaming rule names, in registration order."""
    return tuple(_STREAMING)


def fallback_reason(name: str) -> Optional[str]:
    """Why ``name`` cannot stream (None when it can)."""
    if name in _STREAMING:
        return None
    return NON_STREAMING.get(
        name, "no streaming AggState registered for this rule")


# ----------------------------------------------------------------------
# The weighted-mean family
# ----------------------------------------------------------------------

def flat_ndim() -> int:
    """Rank of ONE client's flattened update under the active layout:
    1 for the classic ``(D,)`` vector, 2 for the model-sharded blocked
    ``(ms, L)`` matrix (:func:`sharding.flatten_updates_sharded`).  A
    trace-time constant — the layout is fixed by the mesh the round is
    traced under."""
    return 2 if model_shard_count() > 1 else 1


def stat_sum(x):
    """Per-client sum over the flat model dims — ``axis=-1`` on the
    classic layout (jaxpr-identical to the historical reductions), the
    last TWO axes on the blocked ``(…, ms, L)`` layout.  There GSPMD
    lowers the row-dim reduce to per-shard partials + a psum over
    ``model`` — the one cross-model-axis collective in the Eq. 6
    criterion statistics (DESIGN.md §12: bounded-ULP, not bitwise)."""
    k = flat_ndim()
    return jnp.sum(x, axis=tuple(range(x.ndim - k, x.ndim)))


def weighted_mean_rule(weight_fn: Callable, *, floor: float = 1.0,
                       use_kernel: bool = False,
                       unroll: int = 8, codec=None) -> StreamingAggregator:
    """Build the AggState monoid for a weighted-mean rule.

    ``weight_fn(u, ctx) -> (a, b, logs)``: client ``i`` contributes
    ``a_i · u_i`` to the numerator and ``b_i`` to the denominator; the
    state is the pair ``(Σ a_i u_i, Σ b_i)`` and ``finalize`` divides
    once (``s / max(n, floor)``).  ``weight_fn`` must be written with
    ``axis=-1`` reductions so the same body serves one (D,) row inside
    ``update`` and a whole (c, D) block inside ``weights`` — under
    vmap/batching both lower to the identical last-axis reduction the
    dense ``similarity_stats_matrix`` performs, which is what keeps the
    criterion statistics bitwise equal across execution layouts.

    ``codec`` (an fl/compression.Codec, threaded from
    ``AggregationContext.codec``) marks the update stream as
    lossy-encoded: ``u`` arrives as the codec's encoded pytree and is
    decoded before the weights and the fold — per-client statistics are
    computed on the *decoded* values, the same bits the dense fallback
    rules see through the shared reference decoder, which is what keeps
    streaming == dense bitwise under every codec (DESIGN.md §10).  On
    the kernel block path the dequantization instead fuses into the
    fold pass itself: dense payloads (bf16) go straight through
    ``masked_agg_update`` (its in-kernel f32 cast IS the decode), int8
    payloads through the fused dequantize-and-fold kernel
    (kernels/dequant_fold.py).  ``codec=None`` is the raw-f32 status
    quo — jaxpr-identical to every pre-compression path.

    init is the monoid identity (zeros); merge adds componentwise —
    associative, and commutative up to fp rounding.  Rows flagged
    invalid (padding) get weight exactly 0.0.

    **Model-sharded D** (DESIGN.md §12): on a client x model mesh the
    (D,) numerator is constrained over the ``model`` axis at ``init``
    and ``finalize``, so the fold's ``s + u_i * a_i`` is a *per-shard
    partial fold* — every multiply-add stays shard-local, the merge
    tree adds co-located shards, and the ONLY cross-model-axis
    collective in Steps 4-5 is the psum GSPMD inserts at the
    ``weight_fn`` dot/norm reductions (the Eq. 6 criterion statistics,
    which are per-client *scalars*).  With a trivial model axis the
    constraints no-op and the fold keeps the §6/§9 bitwise merge-order
    contracts verbatim; across a non-trivial model axis the scalar
    stats reassociate into per-shard partials + psum — bounded-ULP,
    not bitwise (exactly where DESIGN.md §12 relaxes the contract).
    """
    decode = (lambda u: u) if codec is None else codec.decode
    # Non-finite guard (ISSUE 10 satellite): on the raw-f32 stream a
    # client emitting NaN/Inf would poison the AggState numerator
    # irreversibly (NaN · 0 = NaN, so zeroing the *weight* alone is not
    # enough — the value itself must be sanitized before it multiplies
    # anything).  Lossy codecs skip the guard: their wire formats cannot
    # encode non-finite payloads, and the decode path is pinned
    # bitwise against the dense reference decoder.
    guard = codec is None

    def _screen(ud):
        """(sanitized update, finite-row bits or None).

        ``stat_sum(ud * 0.0)`` is 0.0 iff every element is finite
        (0·Inf = 0·NaN = NaN), giving one O(D) reduce per row instead
        of a full isfinite mask reduction.  On finite data the
        sanitizer is bitwise-inert: ``where(True, x, 0) == x`` and the
        weight multiply by 1.0 is exact."""
        if not guard:
            return ud, None
        fin = jnp.isfinite(stat_sum(ud * 0.0))
        mask = fin.reshape(jnp.shape(fin) + (1,) * flat_ndim()) \
            if jnp.ndim(fin) else fin
        return jnp.where(mask, ud, jnp.zeros_like(ud)), fin

    def _valid(a, b, ctx):
        # two multiplicative weight channels: "valid" (padding rows —
        # set by stream_aggregate) and "live" (async cohort membership
        # minus dropouts — set by the engine's round body); both are
        # exact 0/1 floats, so ×1.0 keeps finite weights bitwise
        for key in ("valid", "live"):
            v = ctx.get(key)
            if v is not None:
                vf = v.astype(jnp.float32)
                a, b = a * vf, b * vf
        return a, b

    def init(d) -> AggState:
        # the O(D) numerator lives model-sharded when the mesh says so:
        # the identity's placement is what keeps every fold step's
        # multiply-add shard-local (no-op on a trivial model axis).
        # ``d`` is the flat length (classic layout) or the blocked
        # (ms, L) shape tuple (model-sharded layout).
        shape = d if isinstance(d, tuple) else (d,)
        return (shard_flat(jnp.zeros(shape, jnp.float32)),
                jnp.zeros((), jnp.float32))

    def update(state, u, ctx):
        s, n = state
        ud, fin = _screen(decode(u))
        a, b, logs = weight_fn(ud, ctx)
        if fin is not None:
            ff = fin.astype(jnp.float32)
            a, b = a * ff, b * ff
            logs = dict(logs, nonfinite=~fin)
        a, b = _valid(a, b, ctx)
        return (s + ud.astype(jnp.float32) * a, n + b), logs

    def merge(x, y):
        return jax.tree.map(jnp.add, x, y)

    def finalize(state):
        s, n = state
        # the round delta inherits the numerator's model sharding — the
        # division is elementwise, so no gather happens here either
        return shard_flat(s / jnp.maximum(n, jnp.float32(floor))), {}

    def _block(U, ctx_blk):
        """Shared block form: (sanitized decoded block, a, b, logs) —
        the guard must sanitize the VALUES the fold multiplies, not just
        the weights, so both `weights` and `update_block` route here."""
        ud, fin = _screen(decode(U))
        a, b, logs = weight_fn(ud, ctx_blk)
        if fin is not None:
            ff = fin.astype(jnp.float32)
            a, b = a * ff, b * ff
            logs = dict(logs, nonfinite=~fin)
        a, b = _valid(a, b, ctx_blk)
        return ud, a, b, logs

    def weights(U, ctx_blk):
        _, a, b, logs = _block(U, ctx_blk)
        return a, b, logs

    def update_block(state, U, ctx_blk):
        s, n = state
        ud, a, b, logs = _block(U, ctx_blk)
        if use_kernel:
            from ..kernels import ops as kops
            if codec is None:
                s = kops.masked_agg_update(ud, a, s)
            elif codec.qblock is not None:
                # int8 per-block scales: dequantization fused into the
                # fold's single HBM pass over the 1-byte payload
                s = kops.dequant_fold_update(U["q"], U["scale"], a, s,
                                             qblock=codec.qblock)
            else:
                # dense payload (bf16/f32): the masked-agg kernel's
                # in-kernel f32 cast is the whole dequantization
                s = kops.masked_agg_update(U["q"], a, s)
        else:
            # a: (c,) broadcast against (c, D) or blocked (c, ms, L) —
            # reshape((c, 1)) is a[:, None] verbatim on the classic
            # layout, so the historical jaxpr is unchanged
            ax = a.reshape(a.shape + (1,) * flat_ndim())
            s = s + jnp.sum(ud.astype(jnp.float32) * ax, axis=0)
        return (s, n + jnp.sum(b)), logs

    return StreamingAggregator(init, update, merge, finalize,
                               weights=weights, update_block=update_block,
                               unroll=unroll)


@register_streaming("mean")
def _mean_stream(ctx: AggregationContext) -> StreamingAggregator:
    def weight(u, ci):
        one = jnp.ones(jnp.shape(u)[:u.ndim - flat_ndim()], jnp.float32)
        return one, one, {}
    return weighted_mean_rule(weight, use_kernel=ctx.use_kernel_agg,
                              codec=ctx.codec)


@register_streaming("oracle")
def _oracle_stream(ctx: AggregationContext) -> StreamingAggregator:
    def weight(u, ci):
        keep = ~ci["byz"]
        w = keep.astype(jnp.float32)
        return w, w, {"mask": keep}
    return weighted_mean_rule(weight, use_kernel=ctx.use_kernel_agg,
                              codec=ctx.codec)


@register_streaming("diversefl")
def _diversefl_stream(ctx: AggregationContext) -> StreamingAggregator:
    dfl = ctx.dfl
    kernel_stats = ctx.use_kernel_stats

    def weight(u, ci):
        # Per-client C1/C2 against the guiding update, computed on the
        # fly: multiply + last-axis reduce (NOT vdot/dot_general) so one
        # row here and a row of the dense similarity_stats_matrix are the
        # same reduction — bitwise-equal statistics either way.
        g = ci["guide"].astype(jnp.float32)
        uf = u.astype(jnp.float32)
        if kernel_stats and uf.ndim == 2 and flat_ndim() == 1:
            # block form (update_block / use_kernel_agg): the fused Pallas
            # similarity kernel — one HBM pass over the block pair
            # (model-sharded layouts never reach it: FLConfig validation
            # rejects kernels on a non-trivial model axis)
            from ..kernels import ops as kops
            stats = kops.similarity_stats(uf, g)
            dot, zz, gg = stats[:, 0], stats[:, 1], stats[:, 2]
        else:
            dot = stat_sum(uf * g)
            zz = stat_sum(uf * uf)
            gg = stat_sum(g * g)
        keep = diversefl_mask(dot, zz, gg, dfl)
        w = keep.astype(jnp.float32)
        # z_sq/g_sq mirror the dense rule's log keys exactly (bitwise per
        # client — identical elementwise form), feeding the telemetry
        # block's norm summaries on the streaming path too
        return w, w, {"mask": keep, "z_sq": zz, "g_sq": gg,
                      **criterion_logs(dot, zz, gg)}
    return weighted_mean_rule(weight, use_kernel=ctx.use_kernel_agg,
                              codec=ctx.codec)


@register_streaming("fltrust")
def _fltrust_stream(ctx: AggregationContext) -> StreamingAggregator:
    root = ctx.root_update.astype(jnp.float32)
    rn = jnp.sqrt(jnp.sum(root * root)) + 1e-12

    def weight(u, ci):
        uf = u.astype(jnp.float32)
        un = jnp.sqrt(stat_sum(uf * uf)) + 1e-12
        ts = jax.nn.relu(stat_sum(uf * root) / (un * rn))
        return ts * (rn / un), ts, {}
    # real-valued weights: the 8-way-unrolled fold's multiply-add chain
    # is FMA-latitude XLA resolves differently solo vs vmapped; one
    # iteration per row keeps the streaming fltrust fold layout-stable
    return weighted_mean_rule(weight, floor=1e-12,
                              use_kernel=ctx.use_kernel_agg, unroll=1,
                              codec=ctx.codec)


# ----------------------------------------------------------------------
# The streaming sweep
# ----------------------------------------------------------------------

def tree_merge(merge: Callable, states, n: int):
    """Canonical fixed-association tree-reduce of ``n`` stacked partial
    AggStates (leading axis ``n`` on every leaf).

    The merge order is part of the bitwise contract (DESIGN.md §7): a
    balanced binary tree over the shard index — round 1 merges
    ``(s0, s1), (s2, s3), ...``, an odd tail passes through untouched,
    and rounds repeat until one state remains — so the association is a
    pure function of ``n``, never of device layout or scheduling.
    ``n == 1`` returns the single state unchanged (no merge at all),
    which is what keeps the one-shard path bitwise-identical to the
    sequential sweep."""
    parts = [jax.tree.map(lambda x, i=i: x[i], states) for i in range(n)]
    while len(parts) > 1:
        parts = [merge(parts[i], parts[i + 1])
                 if i + 1 < len(parts) else parts[i]
                 for i in range(0, len(parts), 2)]
    return parts[0]


def stream_aggregate(rule: StreamingAggregator, block_fn: Callable,
                     args: tuple, chunk: Optional[int], *, d: int,
                     prefer_block: bool = False,
                     shards: Optional[int] = None,
                     pods: Optional[int] = None,
                     block_extra: bool = False,
                     extra_state=None):
    """Fold per-client updates into ``rule``'s AggState, one chunk-sized
    block at a time — the (N, D) update matrix never materializes.

    ``args`` is a tuple of pytrees sharing leading client axis C (the
    minibatch stacks plus any O(C) per-client scalars); ``block_fn(blk,
    valid) -> (U_blk (c, D), ctx_blk)`` computes one block's flattened
    updates and per-client context (guide rows, Byzantine bits) from the
    sliced block arguments.  The sweep scans the same padded blocks
    ``chunked_vmap`` would map over, carrying the state; per-client logs
    come back stacked (k, chunk), are unblocked to (C,) and the padding
    rows dropped — exactly chunked_vmap's output contract.

    ``prefer_block=True`` uses ``rule.update_block`` when available (the
    Pallas-kernel block fold); the default folds ``rule.update`` row by
    row, the left-fold association the bitwise contract relies on.

    ``shards`` selects the shard-parallel sweep (``None`` = auto from
    the active mesh's data axes; 1 off-mesh): the ``k`` blocks split
    into S *contiguous* groups, each group folded independently with
    the identical left fold (a vmapped scan whose group axis carries
    the client-axis sharding constraint, so an active mesh runs the
    groups in parallel — ``N/(chunk·S)`` sequential fold steps instead
    of ``N/chunk``), and the S partial states combine via
    :func:`tree_merge`'s canonical ``log2(S)``-deep order.  The result
    is a pure function of (client order, chunk, S) — device layout
    cannot change the bits, ``S == 1`` *is* the sequential sweep, and
    per-client criterion logs are bitwise-identical at every S (the
    fold association never touches per-row statistics).  A shard count
    that does not divide the block count is clamped to the largest
    divisor (fl/chunking.resolve_shards).

    ``pods`` selects the **hierarchical two-tier fold** (DESIGN.md §9):
    the ``k`` blocks split into P *contiguous* pod groups (pod-major —
    the same client ranges the ``("pod", "data")`` sharding places on
    each pod's devices); **tier 1** folds every pod's clients with the
    identical left fold, ``shards``-way shard-parallel *within* the pod
    (``shards`` is per-pod here; auto = the mesh's non-pod data axes),
    its S partials combined by :func:`tree_merge`; **tier 2** combines
    the P per-pod partial AggStates — O(pods·D), the only cross-pod
    traffic — by the same canonical balanced-binary association.  The
    result is a pure function of (client order, chunk, S, pods);
    ``pods=1`` takes the single-tier path above *verbatim* (bitwise);
    per-client logs are bitwise at every (S, pods).  ``pods=None``
    derives P from the mesh's pod axis (1 off-mesh, clamped to a
    divisor of ``k``); an explicit non-dividing ``pods`` raises the
    named ``ShardMismatchError`` (fl/chunking.resolve_pods).

    ``extra_state`` (an AggState, or None) is a pre-folded partial state
    merged into the sweep's result just before ``finalize`` — the async
    engine's landed-straggler channel (DESIGN.md §13): stale updates
    folded outside the block sweep (they belong to no current block)
    join the round mean through the same monoid merge.  ``None`` (every
    pre-async caller) leaves the fold bitwise-untouched — no merge op
    is traced at all.

    ``block_extra=True`` gives the fold a per-block *output* channel:
    ``block_fn`` returns a triple ``(U_blk, ctx_blk, extra)`` whose
    third element is an arbitrary (chunk, ...) pytree riding the scan ys
    alongside the per-client logs (error-feedback residual rows in
    fl/engine.py — values the round must carry out of the fold but that
    never touch the AggState).  The extras are unblocked to (C, ...)
    exactly like client logs and returned as a fourth element:
    ``(delta, agg_logs, client_logs, extra)``.

    Returns ``(delta, agg_logs, client_logs)`` (plus ``extra`` with
    ``block_extra=True``).
    """
    C = jax.tree.leaves(args)[0].shape[0]
    chunk = C if chunk is None or chunk >= C else chunk
    blocks, k, _ = pad_to_blocks(args, chunk)
    valid = block_valid(k, chunk, C)
    use_block = prefer_block and rule.update_block is not None
    mesh_pods, mesh_data = pod_data_counts()
    P = resolve_pods(pods, k, auto=mesh_pods)

    def sweep(state, xs):
        blk, valid_b = xs
        if block_extra:
            U_blk, ctx_blk, extra = block_fn(blk, valid_b)
        else:
            U_blk, ctx_blk = block_fn(blk, valid_b)
            extra = ()
        ctx_blk = dict(ctx_blk, valid=valid_b)
        if use_block:
            state, logs = rule.update_block(state, U_blk, ctx_blk)
        else:
            # unroll matches masked_sum_fold's (same adds in the same
            # order) except where the rule folds real-valued weights and
            # pins unroll=1 for layout stability (StreamingAggregator.
            # unroll)
            state, logs = jax.lax.scan(
                lambda st, uc: rule.update(st, uc[0], uc[1]),
                state, (U_blk, ctx_blk), unroll=rule.unroll)
        return state, (logs, extra)

    fold = lambda g: jax.lax.scan(sweep, rule.init(d), g)   # noqa: E731

    if P > 1:
        # ---- two-tier: pod-local folds, cross-pod partial merge ----
        S = resolve_shards(shards if shards is not None else mesh_data,
                           k // P)
        gxs = group_blocks_2d((blocks, valid), k, P, S)
        gxs = jax.tree.map(shard_lanes, gxs)    # (pod, shard) -> mesh axes
        states, ys = jax.vmap(jax.vmap(fold))(gxs)
        ys = jax.tree.map(
            lambda x: x.reshape((k,) + x.shape[3:]), ys)
        # tier 1 finishes inside the pod: S partials -> one per-pod state
        pod_states = jax.vmap(
            lambda st: tree_merge(rule.merge, st, S))(states)
        # tier 2: only the (P, D)-sized partial states cross pods
        state = tree_merge(rule.merge, pod_states, P)
    else:
        S = resolve_shards(
            shards if shards is not None else data_shard_count(), k)
        if S == 1:
            state, ys = jax.lax.scan(sweep, rule.init(d), (blocks, valid))
        else:
            gxs = group_blocks((blocks, valid), k, S)
            gxs = jax.tree.map(shard_clients, gxs)  # group axis -> data axes
            states, ys = jax.vmap(fold)(gxs)
            ys = jax.tree.map(
                lambda x: x.reshape((k,) + x.shape[2:]), ys)
            state = tree_merge(rule.merge, states, S)
    if extra_state is not None:
        # landed stale updates join as one canonical trailing merge —
        # part of the fixed association (DESIGN.md §13)
        state = rule.merge(state, extra_state)
    delta, agg_logs = rule.finalize(state)
    logs, extras = ys
    if block_extra:
        return (delta, agg_logs, unblock(logs, k, chunk, C),
                unblock(extras, k, chunk, C))
    return delta, agg_logs, unblock(logs, k, chunk, C)
