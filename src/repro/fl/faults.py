"""Device-malfunction models for async federated rounds (DESIGN.md §13).

The paper's threat model has two axes: *adversarial* clients (the
attack registry in ``core/attacks.py``) and *faulty* clients — devices
that malfunction during training.  This module is the fault axis:

  * :class:`FaultConfig` — a frozen, hashable config describing one
    malfunction model, carried on ``FLConfig.fault`` so sweeps treat it
    structurally (same contract as ``AttackConfig``);
  * :func:`make_cohort_chain` — the precomputed ``(R, N)`` per-round
    participation masks threaded as a traced scenario operand (the PR-5
    byz-mask plumbing is the template: magnitudes batch, shapes don't);
  * :func:`draw_faults` / :func:`corrupt_updates` — the per-round fault
    draw from the scan's RNG chain and the client-boundary corruption,
    both pure jittable functions of traced operands.

Faults COMPOSE with attacks: a Byzantine client can also straggle, and
the contract (pinned by tests/test_async.py) is that Eq. 6 tags its
update when it *lands*, not that it silently vanishes from the byz-mask
accounting.

Kinds:

``none``
    No faults.  The async machinery is structurally absent — the
    round body traces the exact PR-9 jaxpr.
``dropout``
    With per-client probability ``rate`` each round, the update never
    arrives: the client leaves the round's live set (zero fold weight
    via the ``live`` context channel; the no-op-round semantics of an
    empty cohort are defined by the fold's ``floor``).
``straggler``
    With probability ``rate``, the client finishes ``delay`` rounds
    late.  Its update enters the bounded-staleness buffer in the scan
    carry and folds through the same AggState monoid when it lands,
    with guides recomputed at the *landing* round (Eq. 6 filters
    stale-and-diverged updates per client, no cohort vote).
``intermittent``
    With probability ``rate``, the update is corrupted in flight:
    ``mode="nan"`` / ``"inf"`` burst the whole update non-finite
    (caught by the streaming fold's non-finite guard), ``"bitflip"``
    scales it by ``bitflip_scale`` — the float image of a flipped
    exponent bit (caught by Eq. 6's C2 norm-ratio band).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

FAULT_KINDS = ("none", "dropout", "straggler", "intermittent")
CORRUPTION_MODES = ("nan", "inf", "bitflip")


class DegenerateCohortError(ValueError):
    """A cohort chain selects zero clients in some round.

    Raised host-side at scenario construction for *explicit* chains.
    Runtime-empty live sets (cohort minus dropouts) are NOT an error:
    the weighted-mean fold's ``floor`` makes an empty round a defined
    no-op (delta = 0/floor = 0) — see DESIGN.md §13.
    """


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """One device-malfunction model.

    ``rate`` is the per-client, per-round malfunction probability,
    drawn i.i.d. from the scan's RNG chain — the paper's "devices
    become faulty during training", not a fixed faulty set.  ``delay``
    (stragglers) is how many rounds late the update lands;
    ``mode``/``bitflip_scale`` shape the intermittent corruption.
    """
    kind: str = "none"
    rate: float = 0.0
    delay: int = 1
    mode: str = "nan"
    bitflip_scale: float = 2.0 ** 7

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; registered kinds: "
                f"{FAULT_KINDS}")
        if not (0.0 <= float(self.rate) <= 1.0):
            raise ValueError(
                f"fault rate must be in [0, 1], got {self.rate}")
        if isinstance(self.delay, bool) or not isinstance(self.delay, int) \
                or self.delay < 1:
            raise ValueError(
                f"fault delay must be a positive int, got {self.delay!r}")
        if self.mode not in CORRUPTION_MODES:
            raise ValueError(
                f"unknown corruption mode {self.mode!r}; registered "
                f"modes: {CORRUPTION_MODES}")


def cohort_size(n_clients: int, participation: float) -> int:
    """Per-round cohort size — ceil like ``FLConfig.n_selected``, never
    zero (an all-zero *expected* cohort is a config error upstream)."""
    return max(1, min(n_clients, math.ceil(participation * n_clients)))


def make_cohort_chain(n_clients: int, rounds: int, participation: float,
                      key) -> jnp.ndarray:
    """Precompute the ``(R, N)`` boolean cohort-mask chain.

    Each round draws a fresh ``cohort_size`` subset without replacement
    and scatters it to an ``(N,)`` mask — the whole chain is one traced
    scenario operand, so per-round resampling costs zero retraces and
    sweeps batch chains along a leading axis exactly like the byz mask.
    """
    c = cohort_size(n_clients, participation)

    def row(k):
        sel = jax.random.choice(k, n_clients, (c,), replace=False)
        return jnp.zeros((n_clients,), bool).at[sel].set(True)

    return jax.vmap(row)(jax.random.split(key, rounds))


def validate_cohort_chain(chain, n_clients: int, rounds: int) -> None:
    """Host-side named-error validation for an explicit cohort chain."""
    chain = jnp.asarray(chain)
    if chain.shape != (rounds, n_clients):
        raise DegenerateCohortError(
            f"cohort chain shape {chain.shape} != (rounds, n_clients) = "
            f"({rounds}, {n_clients})")
    per_round = jnp.sum(chain.astype(jnp.int32), axis=1)
    if bool(jnp.any(per_round == 0)):
        bad = int(jnp.argmax(per_round == 0))
        raise DegenerateCohortError(
            f"cohort chain selects zero clients in round {bad}; every "
            "round needs at least one participant (dropout faults may "
            "still empty a round at runtime — that is a defined no-op, "
            "see DESIGN.md §13)")


def draw_faults(key, n_clients: int, fcfg: FaultConfig) -> jnp.ndarray:
    """Per-round i.i.d. fault draw: ``(N,)`` bool, True = malfunctions
    this round.  Pure function of the traced ``key`` — rides the scan's
    per-round subkey chain, so fault patterns are reproducible and
    sweep-batchable without retraces."""
    if fcfg.kind == "none" or fcfg.rate <= 0.0:
        return jnp.zeros((n_clients,), bool)
    return jax.random.uniform(key, (n_clients,)) < jnp.float32(fcfg.rate)


def corrupt_updates(U, fault_rows, fcfg: FaultConfig):
    """Apply intermittent corruption at the client boundary.

    ``U`` is a block of flat updates (``(c, D)`` or blocked
    ``(c, ms, L)``), ``fault_rows`` the per-row fault bits.  NaN/Inf
    bursts overwrite the whole row; bitflip scales it (one flipped
    exponent bit multiplies the magnitude by a power of two).  Rows
    with ``fault_rows == False`` pass through bitwise untouched
    (``where`` with a False predicate is the identity)."""
    if fcfg.kind != "intermittent":
        return U
    rows = fault_rows.reshape(fault_rows.shape + (1,) * (U.ndim - 1))
    if fcfg.mode == "nan":
        bad = jnp.full_like(U, jnp.nan)
    elif fcfg.mode == "inf":
        bad = jnp.full_like(U, jnp.inf)
    else:
        bad = U * jnp.asarray(fcfg.bitflip_scale, U.dtype)
    return jnp.where(rows, bad, U)


def init_async_state(cfg, flat_shape) -> Optional[dict]:
    """Build the async scan-carry state, or ``None`` when the config's
    async machinery is off (the carry is then structurally the PR-9
    carry — the jaxpr-identity contract of DESIGN.md §13).

    ``flat_shape`` is the flat-update shape: ``(d,)`` or the blocked
    ``(ms, L)`` at model_shards > 1.  The buffer is an O(buffer·D)
    pending slab: ``u`` holds the late updates, ``cid`` their client
    ids, ``ttl`` rounds until landing, ``on`` slot occupancy, and ``r``
    the round counter that indexes the cohort chain."""
    if not cfg.async_rounds:
        return None
    state = {"r": jnp.zeros((), jnp.int32)}
    b = cfg.staleness_buffer
    if b > 0:
        state.update(
            u=jnp.zeros((b,) + tuple(flat_shape), jnp.float32),
            cid=jnp.zeros((b,), jnp.int32),
            ttl=jnp.zeros((b,), jnp.int32),
            on=jnp.zeros((b,), bool),
        )
    return state
