"""Federate the real model zoo: the ``models/`` + ``configs/`` stack as
first-class FL citizens of the compiled round engine (DESIGN.md §12).

The engine's model contract is ``fl/small_models.SmallModel``: ``init``,
``apply(params, x) -> logits``, ``loss(params, x, y, l2)``, an
``input_shape`` and ``n_classes``.  :class:`ZooModel` satisfies it for
any decoder-style :class:`~repro.models.ModelConfig` by casting the
paper's classification framing onto language modeling:

  * an **example** is a token sequence of length ``seq_len + 1``;
    ``x`` is its first ``seq_len`` tokens, ``y`` the final token —
    next-token prediction IS the classification task (``n_classes =
    vocab_size``), so every existing attack (label flip permutes the
    target token), metric (accuracy = next-token top-1) and eval path
    works unchanged;
  * ``loss`` is the full-sequence LM loss over the re-joined
    ``concat(x, y)`` tokens (``models.loss_fn`` — chunked vocab-sharded
    cross entropy), so local SGD trains every position, not just the
    label; ``apply`` returns the last-position next-token logits.

Token ``x`` arrays survive the enclave's f32 seal/unseal round trip
(core/tee.py stores f32) because token ids are exact in f32 up to
2^24 — far beyond any vocab — and both ``loss`` and ``apply`` cast
back to int32 at the boundary.

On a client x model mesh the params take the MODEL_AXIS partition
table's tensor-parallel placement (``sharding.place_params``) and the
engine folds the flattened updates over the sharded flat D — see
DESIGN.md §12 for the full 2D contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .. import models
from ..data.pipeline import FederatedData
from ..data.synthetic import make_token_stream
from ..models import ModelConfig


def _as_tokens(x):
    """Int32 token ids from whatever the pipeline delivered — the
    enclave seals f32 (core/tee.py), so guide batches come back float;
    ids are exact in f32 up to 2^24, so the cast is lossless."""
    return x if jnp.issubdtype(x.dtype, jnp.integer) else \
        jnp.round(x).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class ZooModel:
    """A zoo :class:`ModelConfig` wearing the SmallModel contract.

    ``loss`` accepts the FLConfig ``l2`` knob for interface parity but
    zoo runs should set ``l2=0.0`` — a ridge over 10^8 bf16 parameters
    is neither the paper's setting nor numerically meaningful, and it
    costs a full extra pass over the params per gradient."""
    name: str
    cfg: ModelConfig
    seq_len: int

    @property
    def input_shape(self) -> Tuple[int, ...]:
        return (self.seq_len,)

    @property
    def n_classes(self) -> int:
        return self.cfg.vocab_size

    def param_count(self) -> int:
        return self.cfg.param_count()

    def init(self, key):
        return models.init(key, self.cfg)

    def apply(self, params, x):
        """Last-position next-token logits, (B, vocab_size) f32 — the
        classification head the metrics stack scores."""
        out = models.apply(params, self.cfg, _as_tokens(x))
        lg = models.logits(params, self.cfg, out["hidden"][:, -1:, :])
        return lg[:, 0, :self.cfg.vocab_size]

    def loss(self, params, x, y, l2: float = 0.0):
        """Full-sequence LM loss over ``concat(x, y)`` — every position
        trains, and the final position's target is exactly ``y``."""
        tok = jnp.concatenate(
            [_as_tokens(x), _as_tokens(y)[..., None]], axis=-1)
        nll = models.loss_fn(params, self.cfg, {"tokens": tok})
        if l2:
            nll = nll + 0.5 * l2 * sum(
                jnp.sum(jnp.square(p.astype(jnp.float32)))
                for p in jax.tree.leaves(params))
        return nll

    def accuracy(self, params, x, y, batch: int = 256):
        correct, n = 0, y.shape[0]
        for i in range(0, n, batch):
            lg = self.apply(params, x[i:i + batch])
            correct += int((jnp.argmax(lg, -1) == y[i:i + batch]).sum())
        return correct / n


def zoo_model(arch, seq_len: int = 64, smoke: bool = True) -> ZooModel:
    """A :class:`ZooModel` from an arch id (``configs.get``), or wrap an
    explicit :class:`ModelConfig` (``arch`` may be either)."""
    if isinstance(arch, ModelConfig):
        cfg = arch
    else:
        from .. import configs
        cfg = configs.get(arch, smoke=smoke)
    if cfg.is_enc_dec or cfg.has_cross:
        raise ValueError(
            f"{cfg.name!r} needs encoder/cross-attention inputs "
            f"(enc_emb/cross_emb) that the FL data pipeline does not "
            f"carry — federate a decoder-only arch, or extend "
            f"FederatedData with modality sidecars first")
    return ZooModel(name=cfg.name, cfg=cfg, seq_len=seq_len)


def make_zoo_data(key, model: ZooModel, n_clients: int, per_client: int,
                  n_test: int = 64):
    """Synthetic federated token data for ``model``: per-client stacks
    of (seq_len+1)-token examples split into (x = prefix, y = next
    token), plus a held-out test split — the zoo twin of
    ``data.make_mnist_like`` + ``FederatedData.from_partitions``."""
    total = n_clients * per_client + n_test
    toks = make_token_stream(key, total, model.seq_len + 1,
                             model.cfg.vocab_size)
    S = model.seq_len
    tr = toks[:n_clients * per_client].reshape(n_clients, per_client, S + 1)
    data = FederatedData(x=tr[:, :, :S], y=tr[:, :, S],
                         n_classes=model.cfg.vocab_size)
    te = toks[n_clients * per_client:]
    return data, te[:, :S], te[:, S]


def make_zoo_federation(model: ZooModel, cfg, key=None,
                        per_client: int = 32, n_test: int = 64):
    """Data + sealed enclave samples + SecureServer for a zoo model —
    ``Federation.create`` on synthetic token shards.  Returns the
    federation; drive it with ``run_federated_training(model, fed, cfg,
    ...)`` or a :class:`~repro.fl.engine.RoundEngine` built on a client
    x model mesh."""
    from .simulator import Federation
    if key is None:
        key = jax.random.PRNGKey(cfg.seed + 17)
    kd, kf = jax.random.split(key)
    data, tx, ty = make_zoo_data(kd, model, cfg.n_clients, per_client,
                                 n_test)
    return Federation.create(model, data, tx, ty, cfg, kf)
