"""SecureServer — Algorithm 1's trust boundary, plus the aggregator registry.

Every aggregation path in the repo routes through this module
(DESIGN.md §3):

  * ``SecureServer`` owns the TEE ``Enclave``.  At setup it performs the
    attestation handshake (Step 0) and ingests each client's once-shared
    sample as a *sealed* blob (Step 1).  Guiding-update data is only ever
    obtained by unsealing those blobs — there is no raw-sample side
    channel — and the unsealed guide batches are cached device-side
    (keyed on the enclave's seal version) so the jitted round step pays
    the unseal cost once, not per round.
  * ``AggregatorRegistry`` (module-level, decorator-registered) maps each
    aggregation rule name to a strategy with the uniform signature
    ``fn(U, ctx) -> (delta, logs)`` where ``U`` is the stacked (N, D)
    update matrix and ``ctx`` is an :class:`AggregationContext`.  This
    replaces the per-call-site if/elif dispatch the seed carried in
    fl/simulator.py and benchmarks/.

The DiverseFL rule itself imports its mask/statistics/aggregation math
from core/diversefl.py (one source of truth) and can route Step 4+5
through the fused Pallas kernels (kernels/similarity.py +
kernels/masked_agg.py) via the ``use_kernel_stats``/``use_kernel_agg``
context flags.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import aggregators as agg
from ..core.diversefl import (DiverseFLConfig, criterion_logs, diversefl_mask,
                              guiding_update, masked_mean_flat,
                              similarity_stats_matrix)
from ..core.tee import Enclave
from .chunking import chunked_vmap
from .telemetry import AuditLog

DEFAULT_IDENTITY = "diversefl-enclave-v1"

# The masked/weighted-mean family: rules whose delta is a per-client-
# weighted mean, so the fused Pallas masked-agg kernels (use_kernel_agg)
# apply — 0/1 masks for diversefl/oracle/mean, trust-score weights for
# fltrust.  Any other rule never reaches the kernel —
# FLConfig.__post_init__ rejects the combination instead of silently
# ignoring the flag.
KERNEL_AGG_RULES = ("diversefl", "oracle", "mean", "fltrust")


# ----------------------------------------------------------------------
# Aggregator registry
# ----------------------------------------------------------------------

@dataclasses.dataclass
class AggregationContext:
    """Everything a registered rule may need beyond the update matrix.

    All array members are traced values inside the jitted round step;
    the scalars/configs are compile-time constants.  ``byz_mask`` in
    particular is *scenario data*, never a baked constant: the round
    body slices it from the run's scenario operands
    (fl/engine.make_scenario), which is what lets a batched sweep vary
    Byzantine identities per cell without retracing (DESIGN.md §8) —
    whereas ``f`` is a static int, so rules that consume it as a shape
    (trimmed_mean/krum/bulyan) force a new structural group per value
    (fl/sweep.F_STATIC_RULES)."""
    key: Optional[jax.Array] = None          # rng (resampling)
    f: int = 0                               # Byzantine budget
    dfl: DiverseFLConfig = DiverseFLConfig()
    byz_mask: Optional[jnp.ndarray] = None   # ground truth (oracle only)
    guides: Optional[jnp.ndarray] = None     # G (N, D) — enclave Step 3
    root_update: Optional[jnp.ndarray] = None  # FLTrust root direction
    resample_s: int = 2
    use_kernel_stats: bool = False           # Pallas similarity kernel
    use_kernel_agg: bool = False             # Pallas fused masked mean
    stream_shards: Optional[int] = None      # streaming fold groups: None =
    #                                          auto from the active mesh's
    #                                          data axes (fl/streaming.py);
    #                                          per-pod when stream_pods > 1
    stream_pods: Optional[int] = None        # two-tier fold pod count: None =
    #                                          auto from the mesh's pod axis
    #                                          (1 off-mesh); an explicit count
    #                                          must divide the block count
    #                                          (DESIGN.md §9)
    codec: Optional[object] = None           # fl/compression.Codec when the
    #                                          update stream is LOSSY-encoded:
    #                                          streaming rules decode blocks
    #                                          through it (or fold the int8
    #                                          payload via the fused dequant
    #                                          kernel).  None == raw f32
    #                                          arrays — the uncompressed and
    #                                          f32-passthrough paths, whose
    #                                          jaxprs stay identical
    #                                          (DESIGN.md §10)


@dataclasses.dataclass(frozen=True)
class AggregatorEntry:
    name: str
    fn: Callable[[jnp.ndarray, AggregationContext],
                 Tuple[jnp.ndarray, Dict]]
    needs_guides: bool = False               # requires ctx.guides
    needs_root: bool = False                 # requires ctx.root_update


_REGISTRY: Dict[str, AggregatorEntry] = {}


def register_aggregator(name: str, *, needs_guides: bool = False,
                        needs_root: bool = False):
    """Decorator: register ``fn(U, ctx) -> (delta, logs)`` under ``name``."""
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"aggregator {name!r} already registered")
        _REGISTRY[name] = AggregatorEntry(name, fn, needs_guides, needs_root)
        return fn
    return deco


def get_aggregator(name: str) -> AggregatorEntry:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown aggregator {name!r}; "
                         f"available: {available_aggregators()}") from None


def available_aggregators() -> Tuple[str, ...]:
    """Registered rule names, in registration order."""
    return tuple(_REGISTRY)


def aggregate(name: str, U, ctx: AggregationContext):
    """Dispatch one aggregation: (N, D) updates -> ((D,) delta, logs)."""
    return get_aggregator(name).fn(U, ctx)


# ----------------------------------------------------------------------
# Registered rules (paper Sec. IV + Appendix A)
# ----------------------------------------------------------------------

@register_aggregator("diversefl", needs_guides=True)
def _diversefl(U, ctx):
    """Per-client C1/C2 criteria + masked mean (Eq. 2-6)."""
    if ctx.use_kernel_agg:
        from ..kernels import ops as kops
        delta, mask, (dot, zz, gg) = kops.diversefl_step45(U, ctx.guides,
                                                           ctx.dfl)
    else:
        if ctx.use_kernel_stats:
            from ..kernels import ops as kops
            stats = kops.similarity_stats(U, ctx.guides)
            dot, zz, gg = stats[:, 0], stats[:, 1], stats[:, 2]
        else:
            dot, zz, gg = similarity_stats_matrix(U, ctx.guides)
        mask = diversefl_mask(dot, zz, gg, ctx.dfl)
        delta = masked_mean_flat(U, mask)
    # z_sq/g_sq feed the per-round norm summaries in the telemetry block
    # (fl/telemetry.make_round_telemetry_fn); like every log key they are
    # filtered out of the history by make_eval_fn's key selection
    return delta, {"mask": mask, "z_sq": zz, "g_sq": gg,
                   **criterion_logs(dot, zz, gg)}


@register_aggregator("oracle")
def _oracle(U, ctx):
    mask = ~ctx.byz_mask
    if ctx.use_kernel_agg:
        from ..kernels import ops as kops
        return kops.masked_aggregate(U, mask), {"mask": mask}
    return masked_mean_flat(U, mask), {"mask": mask}


@register_aggregator("mean")
def _mean(U, ctx):
    ones = jnp.ones((U.shape[0],), jnp.float32)
    if ctx.use_kernel_agg:
        from ..kernels import ops as kops
        return kops.masked_aggregate(U, ones), {}
    # masked_mean_flat with an all-ones mask == the plain mean, reduced in
    # the canonical fold order the streaming path reproduces bitwise.
    return masked_mean_flat(U, ones), {}


@register_aggregator("median")
def _median(U, ctx):
    return agg.median(U), {}


@register_aggregator("trimmed_mean")
def _trimmed_mean(U, ctx):
    return agg.trimmed_mean(U, ctx.f), {}


@register_aggregator("krum")
def _krum(U, ctx):
    return agg.krum(U, ctx.f), {}


@register_aggregator("bulyan")
def _bulyan(U, ctx):
    return agg.bulyan(U, ctx.f), {}


@register_aggregator("resampling")
def _resampling(U, ctx):
    return agg.resampling(U, ctx.key, ctx.resample_s), {}


@register_aggregator("fltrust", needs_root=True)
def _fltrust(U, ctx):
    if ctx.use_kernel_agg:
        # weighted-mean form: a_i = TS_i · ‖root‖/‖z_i‖ folds the rescale
        # into the per-client weight, one kernel pass over U accumulates
        # Σ a_i·z_i, one division by Σ TS_i finalizes [26]
        from ..kernels import ops as kops
        r = ctx.root_update.astype(jnp.float32)
        rn = jnp.sqrt(jnp.sum(r * r)) + 1e-12
        Uf = U.astype(jnp.float32)
        un = jnp.sqrt(jnp.sum(Uf * Uf, axis=1)) + 1e-12
        ts = jax.nn.relu((Uf @ r) / (un * rn))
        s = kops.masked_agg_update(
            Uf, ts * (rn / un), jnp.zeros((U.shape[1],), jnp.float32))
        return s / jnp.maximum(ts.sum(), 1e-12), {}
    return agg.fltrust(U, ctx.root_update), {}


# ----------------------------------------------------------------------
# SecureServer
# ----------------------------------------------------------------------

class SecureServer:
    """The FL server's enclave-backed aggregation choke point.

    Setup (Steps 0-1): construct -> attestation handshake; then
    ``ingest_samples`` seals each client's once-shared sample into the
    enclave.  Training (Steps 3-5): ``guide_batches`` exposes the
    *unsealed* samples (cached device-side, invalidated whenever the
    sealed store changes), ``compute_guides`` runs the enclave-side
    guiding updates, and ``aggregate`` dispatches through the registry.
    """

    def __init__(self, enclave: Optional[Enclave] = None,
                 identity: str = DEFAULT_IDENTITY, nonce: int = 0x5ecf1):
        self.enclave = enclave if enclave is not None else Enclave(identity)
        # append-only, hash-chained record of every enclave-side decision
        # (fl/telemetry.AuditLog, DESIGN.md §11): attestation, seals/
        # drops, guide-cache rebuilds, per-round tag counts.  Entries
        # commit to the previous digest, so the server cannot silently
        # rewrite what it did — the simulation analogue of SecFL's
        # attested aggregation log.  Only ids/counts/versions are logged,
        # never samples or updates.
        self.audit = AuditLog()
        quote = self.enclave.attest(nonce)
        if not Enclave.verify_quote(quote, identity, nonce):
            raise RuntimeError(
                f"attestation failed: enclave does not measure as {identity!r}")
        self.audit.append("attestation", identity=identity, nonce=nonce,
                          measurement=quote.measurement)
        self._guide_cache = None             # (seal_version, gx, gy)

    # --- Step 1: sealed-sample ingestion ------------------------------
    def ingest_samples(self, client_id: int, x, y) -> None:
        """Seal one client's shared sample M_j^0 into the enclave."""
        self.enclave.seal_samples(client_id, x, y)
        self.audit.append("seal", client=int(client_id),
                          version=self.enclave.seal_version)

    def drop_client(self, client_id: int) -> None:
        self.enclave.drop_client(client_id)
        self.audit.append("drop", client=int(client_id),
                          version=self.enclave.seal_version)

    # --- unsealed guide batches (cached device-side) ------------------
    def guide_batches(self, refresh: bool = False):
        """Guide batches stacked BY CLIENT ID: row j is client j's sample,
        obtained ONLY by unsealing — callers index the stack with client
        ids, so the alignment must survive ``drop_client``.  A dropped
        (or never-ingested) id gets an all-zero row: a zero guiding
        update fails both C1 (dot = 0) and C2 (‖Δ̃‖ = 0), so such a
        client can never pass the criterion — the paper's semantics for
        clients removed from the enclave (Sec. IV-C).

        The unseal runs once per seal_version and the result lives on
        device, so jitted round steps close over stable arrays; any
        mutation of the sealed store (ingest/drop/tamper via re-seal)
        invalidates the cache."""
        version = self.enclave.seal_version
        if refresh or self._guide_cache is None \
                or self._guide_cache[0] != version:
            if not jax.core.trace_state_clean():
                raise RuntimeError(
                    "guide_batches cache rebuild attempted under an active "
                    "JAX trace — the unsealed arrays would be cached as "
                    "tracers and leak.  Warm the cache eagerly first "
                    "(fl/engine.make_round_body does this).")
            ids = self.enclave.client_ids()
            if not ids:
                raise RuntimeError(
                    "SecureServer has no sealed samples — ingest_samples "
                    "must run before guide_batches")
            unsealed = {j: self.enclave.unseal_samples(j) for j in ids}
            zx, zy = jax.tree.map(jnp.zeros_like, unsealed[ids[0]])
            rows = [unsealed.get(j, (zx, zy)) for j in range(max(ids) + 1)]
            self._guide_cache = (version,
                                 jnp.stack([r[0] for r in rows]),
                                 jnp.stack([r[1] for r in rows]))
            self.audit.append("guide_cache_rebuild", version=version,
                              clients=len(ids))
        return self._guide_cache[1], self._guide_cache[2]

    # --- audit: per-round tag decisions -------------------------------
    def record_round_tags(self, round_index: int, **counts) -> None:
        """Commit one round's tag decision counts (kept/tagged clients,
        C1/C2 pass counts) to the hash-chained audit log.  Called by the
        simulator's telemetry drain after the run's one host sync — the
        counts come from the on-device telemetry block, so committing
        them costs no extra device round-trip."""
        self.audit.append(
            "round_tags", round=int(round_index),
            **{k: (v.item() if hasattr(v, "item") else v)
               for k, v in counts.items()})

    def record_cohort_resample(self, round_index: int, cohort: int,
                               **extra) -> None:
        """Commit one round's resampled cohort size (live participants
        after dropout faults) to the audit chain — the async control
        path's answer to "which clients did the enclave even hear from
        this round" (DESIGN.md §13)."""
        self.audit.append("cohort_resample", round=int(round_index),
                          cohort=int(cohort), **extra)

    def record_stale(self, round_index: int, decision: str,
                     count: int, **extra) -> None:
        """Commit one round's staleness decision count to the audit
        chain.  ``decision`` is one of ``buffered`` (straggler update
        entered the pending slab), ``folded`` (a buffered update landed
        and went through Eq. 6 at the landing round) or ``expired``
        (dropped: no free slot, buffer=0, or over the staleness cap)."""
        if decision not in ("buffered", "folded", "expired"):
            raise ValueError(
                f"unknown staleness decision {decision!r}; expected "
                f"'buffered', 'folded' or 'expired'")
        self.audit.append(f"stale_{decision}", round=int(round_index),
                          count=int(count), **extra)

    # --- Step 3: guiding updates --------------------------------------
    def compute_guides(self, params, grad_fn, lr, E: int = 1, select=None,
                       client_chunk: Optional[int] = None, codec=None,
                       flat: bool = False):
        """Δ̃_j from unsealed samples only — the sole guide-data path.

        ``select`` restricts to the round's participating subset S^i
        (client-id index array, traced or concrete); ``client_chunk``
        bounds how many guiding updates are in flight at once
        (fl/chunking.chunked_vmap), so the enclave-side Step 3 scales
        with the chunk, not the federation.  ``client_chunk=None`` is
        exactly the seed vmap.

        ``codec`` (an fl/compression.Codec) quantize-dequantizes the
        guides per tensor before they leave this method — the enclave
        computing its side of the C1/C2 criterion at the wire precision,
        so compressed runs compare quantized updates against equally
        quantized guides (the paper-adjacent science question DESIGN.md
        §10 records).  Lossless codecs (and None) change nothing.

        ``flat=True`` returns the flattened f32 ``(c, D)`` guide matrix
        directly — each client's guide pytree is raveled (and, under a
        lossy codec, quantize-dequantized per tensor first — the exact
        bits ``flatten_updates(quantize_tree(...))`` would produce)
        *inside* the chunked map, so at zoo scale the enclave's working
        set is O(chunk x model): the stacked guide pytree and its flat
        copy never coexist, which is the 100M+-param guide memory model
        (DESIGN.md §12).  The matrix carries the client x model update
        sharding; ``flat=False`` is the legacy pytree contract,
        unchanged."""
        gx, gy = self.guide_batches()
        if select is not None:
            gx, gy = gx[select], gy[select]
        if flat:
            from .compression import quantize_tree
            from ..sharding import (model_shard_count, ravel_sharded,
                                    shard_updates)
            sharded = model_shard_count() > 1

            def one_flat(x, y):
                g = guiding_update(params, (x, y), grad_fn, lr, E)
                if codec is not None and not codec.lossless:
                    # per-tensor quantization BEFORE the ravel: the wire
                    # blocks (int8 qblock) align with tensor boundaries
                    # exactly as on the pytree path — bitwise-identical
                    # guides either way
                    g = quantize_tree(codec, g)
                if sharded:
                    # blocked (ms, L) layout, concatenated along the
                    # unsharded column dim: same element values, none of
                    # the flat build's unsharded full-D temp — and the
                    # same column offsets as the update blocks, so the
                    # Eq. 6 dots align (sharding.ravel_sharded, §12)
                    return ravel_sharded(g)
                return jnp.concatenate(
                    [jnp.ravel(l).astype(jnp.float32)
                     for l in jax.tree.leaves(g)])
            return shard_updates(chunked_vmap(one_flat, (gx, gy),
                                              client_chunk))
        guides = chunked_vmap(
            lambda x, y: guiding_update(params, (x, y), grad_fn, lr, E),
            (gx, gy), client_chunk)
        if codec is not None and not codec.lossless:
            from .compression import quantize_tree   # deferred: no cycle, but
            guides = quantize_tree(codec, guides)    # keep server import-light
        return guides

    def compute_root_update(self, params, grad_fn, lr, E, root_x, root_y):
        """FLTrust's server-side root direction: the same Step-3 SGD on
        the server's root dataset (one pseudo-client, never chunked)."""
        return guiding_update(params, (root_x, root_y), grad_fn, lr, E)

    # --- Steps 4-5: criterion + aggregation ---------------------------
    @staticmethod
    def aggregate(name: str, U, ctx: AggregationContext):
        return aggregate(name, U, ctx)

    @staticmethod
    def streaming_aggregator(name: str, ctx: AggregationContext):
        """The bound streaming AggState monoid for ``name`` — the
        constant-memory counterpart of :meth:`aggregate` (fl/streaming.py,
        DESIGN.md §6) — or None when the rule only exists densely and the
        caller must fall back to the (N, D) path."""
        from .streaming import get_streaming    # deferred: streaming imports
        entry = get_streaming(name)             # this module's registry
        return None if entry is None else entry.bind(ctx)
