"""RSA [23] — Byzantine-robust stochastic aggregation (l1 consensus).

RSA is a *training protocol*, not a one-shot aggregator: every client j
keeps its own model copy theta_j and the master keeps theta_M; both take
signed-consensus steps (Eqs. 11-12).  Byzantine clients upload arbitrary
model copies.  Used only for the convex softmax-regression comparison
(the paper excludes RSA from the NN experiments).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.attacks import AttackConfig, flip_labels
from .simulator import Federation, FLConfig


def run_rsa(model, fed: Federation, cfg: FLConfig, lr_schedule,
            delta: float = 0.25, l2: float = 0.0067):
    key = jax.random.PRNGKey(cfg.seed)
    p0 = model.init(jax.random.PRNGKey(cfg.seed + 1))
    theta_m = p0
    theta_c = jax.tree.map(lambda p: jnp.stack([p] * cfg.n_clients), p0)
    byz = fed.byz_mask
    acfg = cfg.attack
    n_classes = fed.data.n_classes

    @jax.jit
    def step(theta_c, theta_m, key, lr):
        kb, ka = jax.random.split(key)
        xb, yb = fed.data.minibatch(kb, cfg.batch_size)
        if acfg.kind == "label_flip":
            yb = jnp.where(byz[:, None], flip_labels(yb, n_classes), yb)

        def client_step(tj, x, y):
            g = jax.grad(lambda p: model.loss(p, x, y, 0.0))(tj)
            return jax.tree.map(
                lambda t, gg, tm: t - lr * (gg / cfg.n_clients +
                                            delta * jnp.sign(t - tm)),
                tj, g, theta_m)

        theta_c2 = jax.vmap(client_step, in_axes=(0, 0, 0))(theta_c, xb, yb)

        # Byzantine clients upload arbitrary copies (gaussian / sign-flip etc.)
        if acfg.kind == "gaussian":
            noise = jax.tree.map(
                lambda t: jax.random.normal(ka, t.shape) * acfg.sigma, theta_c2)
            theta_c2 = jax.tree.map(
                lambda t, n: jnp.where(
                    byz.reshape((-1,) + (1,) * (t.ndim - 1)), n, t),
                theta_c2, noise)
        elif acfg.kind == "sign_flip":
            theta_c2 = jax.tree.map(
                lambda t: jnp.where(
                    byz.reshape((-1,) + (1,) * (t.ndim - 1)), -t, t), theta_c2)
        elif acfg.kind == "same_value":
            theta_c2 = jax.tree.map(
                lambda t: jnp.where(
                    byz.reshape((-1,) + (1,) * (t.ndim - 1)),
                    jnp.full_like(t, acfg.sigma), t), theta_c2)

        theta_m2 = jax.tree.map(
            lambda tm, tc: tm - lr * (l2 * tm +
                                      delta * jnp.sign(tm - tc).sum(0)),
            theta_m, theta_c2)
        return theta_c2, theta_m2

    history = {"round": [], "acc": []}
    for i in range(1, cfg.rounds + 1):
        key, sub = jax.random.split(key)
        theta_c, theta_m = step(theta_c, theta_m, sub, float(lr_schedule(i)))
        if i % cfg.eval_every == 0 or i == cfg.rounds:
            acc = model.accuracy(theta_m, fed.test_x, fed.test_y)
            history["round"].append(i)
            history["acc"].append(acc)
    history["final_acc"] = history["acc"][-1]
    history["params"] = theta_m
    return history
