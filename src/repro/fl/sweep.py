"""Batched experiment sweeps — vmap whole federated runs (DESIGN.md §8).

The paper's results are grids: every table sweeps attack kind x fault
count x aggregator x seed.  After the one-dispatch engine (§7) each
cell still paid its own trace/compile and ran strictly sequentially —
a 60-cell grid cost 60 compiles and 60 dispatches of a program that
individually underfills the device.  This module batches them:

  * **SweepSpec** — a grid of per-cell values over a base ``FLConfig``:
    seeds, Byzantine counts (or explicit masks), attack configs (whose
    sigma/scale magnitudes batch), learning-rate schedules,
    participation levels.  ``cells()`` is the cartesian product, seeds
    innermost, so same-structure cells sit adjacent.
  * **Structural groups** — cells are partitioned by
    :func:`structural_key`: everything that shapes the *trace*
    (aggregator, attack kind and its class targets, participation — it
    sets the selection shape — rounds/eval cadence, chunking, DiverseFL
    thresholds, ...) splits groups; everything that is *data* (seed,
    attack sigma/scale, the Byzantine mask — and therefore ``f`` for
    every rule that does not consume it as a static shape) batches.
    One group == one compiled program.
  * **The batched axis** — each group runs as a single
    ``RoundEngine.run_training_sweep``: the §7 one-dispatch training
    program ``jax.vmap``-ed over a stacked scenario axis (per-cell init
    params, RNG chains, lr vectors and :func:`~repro.fl.engine.
    make_scenario` operands), one compile and one final ``host_sync``
    per group, with the scenario axis placed over an active mesh's data
    axes (``sharding.sweep_put``) so cells run in parallel across
    devices.

**Bitwise contract.**  vmap is a program transform, not a numeric one:
every per-cell slice of the batched program performs the same
elementwise ops, last-axis reductions and canonical client-order folds
(core/diversefl.masked_sum_fold) the solo program performs, so each
cell's metric history and final params are *bitwise equal* to running
that cell alone through ``run_federated_training`` (tests/test_sweep.py
pins this across attacks x aggregators x seeds, partial participation
included).  The price is memory, not bits: a group's working set is
~group_size x the per-run working set, traded against ``client_chunk``
(DESIGN.md §8 records the model).
"""
from __future__ import annotations

import dataclasses
import numbers
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.attacks import AttackConfig
from . import simulator as _sim
from . import telemetry
from .engine import RoundEngine, make_scenario, trace_counter
from .faults import FaultConfig
from .simulator import FLConfig, _lr_vector, _record_eval

# Rules that consume the Byzantine budget ``f`` as a *static shape*
# (sorted-column trims, neighbour counts) — for them ``f`` is structure
# and splits groups.  Every other rule sees Byzantine identity only as
# the scenario mask, so ``f`` is data and batches.
F_STATIC_RULES = ("trimmed_mean", "krum", "bulyan")


@dataclasses.dataclass(frozen=True, eq=False)
class SweepCell:
    """One grid point: a full config plus its non-config operands."""
    cfg: FLConfig
    lr_schedule: Optional[Callable] = None    # None -> the sweep default
    byz_mask: Optional[jnp.ndarray] = None    # None -> derive from cfg.f


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A grid of federated runs over a base config.

    Each axis is optional; ``None`` keeps the base value.  ``fs``
    entries may be ints (Byzantine counts — the mask derives via the
    deterministic ``make_byzantine_mask``, exactly what
    ``Federation.create`` would build) or explicit (N,) masks (count
    and identities both per-cell).  ``attacks`` entries are whole
    ``AttackConfig``s: kinds/class targets are structural, sigma/scale
    magnitudes batch.  ``pods`` entries are two-tier fold pod counts
    (``FLConfig.pods``): a **structural** axis — different pod counts
    are different fold associations, hence different traces, so each
    value lands in its own structural group and is never batched with
    another (``structural_key`` erases only data fields, pinned by
    tests/test_sweep.py).  ``compressions`` entries are codec names
    (``FLConfig.compression``) — likewise **structural**: codecs change
    the wire pytree, the fold's decode graph and (lossy) the carry
    itself, so two codecs never share a compiled program; the axis
    exists so one spec can sweep f32 vs bf16 vs int8 side by side (the
    accuracy-vs-bytes trade the compression PR gates on).  ``faults``
    entries are whole ``fl.faults.FaultConfig``s and ``stalenesses``
    staleness-buffer sizes (``FLConfig.staleness_buffer``) — both
    **structural by default** (``structural_key`` erases only data
    fields, so a fault kind or buffer size lands in its own compiled
    group): the robustness-vs-staleness grids the async PR gates on run
    as one dispatch per (fault, buffer) point (DESIGN.md §13).  The
    product order is the declaration order below with ``seeds``
    innermost, so cells of one structural group are adjacent and
    ``cells()[i]`` maps 1:1 to the result list of
    ``run_federated_sweep``."""
    base: FLConfig
    seeds: Sequence[int] = (0,)
    aggregators: Optional[Sequence[str]] = None
    attacks: Optional[Sequence[AttackConfig]] = None
    fs: Optional[Sequence] = None             # ints or explicit (N,) masks
    participations: Optional[Sequence[float]] = None
    pods: Optional[Sequence[Optional[int]]] = None   # two-tier pod counts
    compressions: Optional[Sequence[str]] = None     # codec names (structural)
    faults: Optional[Sequence[FaultConfig]] = None   # fault models (structural)
    stalenesses: Optional[Sequence[int]] = None      # buffer sizes (structural)
    lr_schedules: Optional[Sequence[Callable]] = None

    def cells(self) -> list:
        # every axis: None keeps the base value; an explicitly empty
        # sequence yields zero cells (no silent base fallback — a
        # programmatically filtered-to-empty axis must not resurrect
        # the base value)
        def axis(values, default):
            return values if values is not None else (default,)

        out = []
        for agg in axis(self.aggregators, self.base.aggregator):
            for atk in axis(self.attacks, self.base.attack):
                for f in axis(self.fs, self.base.f):
                    for part in axis(self.participations,
                                     self.base.participation):
                        for pod in axis(self.pods, self.base.pods):
                            for comp in axis(self.compressions,
                                             self.base.compression):
                                for flt in axis(self.faults,
                                                self.base.fault):
                                    for stal in axis(
                                            self.stalenesses,
                                            self.base.staleness_buffer):
                                        for sched in axis(
                                                self.lr_schedules, None):
                                            for seed in self.seeds:
                                                out.append(self._cell(
                                                    agg, atk, f, part, pod,
                                                    comp, flt, stal, sched,
                                                    seed))
        return out

    def _cell(self, agg, atk, f, part, pod, comp, flt, stal, sched, seed):
        mask = None
        if isinstance(f, numbers.Integral):
            fi = int(f)                        # plain/numpy int
        else:
            mask = jnp.asarray(f, bool)
            if mask.shape != (self.base.n_clients,):
                raise ValueError(
                    f"explicit Byzantine mask must be "
                    f"({self.base.n_clients},), got {mask.shape}")
            fi = int(mask.sum())
        cfg = dataclasses.replace(
            self.base, aggregator=agg, attack=atk, f=fi,
            participation=part, pods=pod, compression=comp,
            fault=flt, staleness_buffer=stal, seed=seed)
        return SweepCell(cfg, sched, mask)


def structural_key(cfg: FLConfig):
    """The trace identity of a config: two cells share a compiled
    program iff their keys are equal.

    Implemented by *erasing the batchable fields* — seed, the attack
    magnitudes, and ``f`` for every rule outside ``F_STATIC_RULES`` —
    and comparing the rest of the (frozen, hashable) config wholesale,
    so a new FLConfig knob is structural by default: the conservative
    failure mode is an extra group (a redundant compile), never a wrong
    batch."""
    return dataclasses.replace(
        cfg, seed=0,
        f=cfg.f if cfg.aggregator in F_STATIC_RULES else 0,
        attack=dataclasses.replace(cfg.attack, sigma=0.0, scale=0.0))


def group_cells(cells: Sequence[SweepCell]):
    """Partition cells into structural groups, preserving cell order:
    ``{structural_key: [(cell_index, cell), ...]}``."""
    groups = {}
    for i, cell in enumerate(cells):
        groups.setdefault(structural_key(cell.cfg), []).append((i, cell))
    return groups


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def execute_sweep(model, fed, spec: SweepSpec,
                  lr_schedule: Optional[Callable] = None,
                  log_every: int = 0) -> list:
    """Run every cell of ``spec``, one batched program per structural
    group; returns per-cell histories in ``spec.cells()`` order.

    The implementation behind ``fl.simulator.run_federated_sweep`` (the
    public entry — see its docstring for the contract)."""
    cells = spec.cells()
    if not cells:
        return []
    for cell in cells:
        if cell.cfg.n_clients != fed.data.n_clients:
            raise ValueError(
                f"sweep cell has n_clients={cell.cfg.n_clients} but the "
                f"federation holds {fed.data.n_clients} clients")
        if cell.cfg.rounds < 1:
            raise ValueError("sweep cells need rounds >= 1")
        if cell.lr_schedule is None and lr_schedule is None:
            raise ValueError(
                "no learning-rate schedule: pass lr_schedule= or give "
                "the spec an lr_schedules axis")

    results = [None] * len(cells)
    for gi, members in enumerate(group_cells(cells).values()):
        rep = members[0][1].cfg                # structural representative
        with telemetry.span("sweep_group", group=gi, cells=len(members),
                            aggregator=rep.aggregator,
                            attack=rep.attack.kind, rounds=rep.rounds,
                            pods=rep.pods, codec=rep.compression,
                            streaming=bool(rep.streaming)):
            with telemetry.span("compile+dispatch"), \
                    trace_counter() as compiles:
                engine = RoundEngine(model, fed, rep)
                R = rep.rounds
                params0 = _stack(
                    [model.init(jax.random.PRNGKey(c.cfg.seed + 1))
                     for _, c in members])
                keys = jnp.stack([jax.random.PRNGKey(c.cfg.seed)
                                  for _, c in members])
                lrs = jnp.stack([_lr_vector(c.lr_schedule or lr_schedule, R)
                                 for _, c in members])
                scen = _stack([make_scenario(c.cfg, byz_mask=c.byz_mask)
                               for _, c in members])
                params, _keys, metrics, eval_rounds = \
                    engine.run_training_sweep(params0, keys, lrs, scen)
            telemetry.event("sweep_group_compiles", group=gi,
                            **compiles.snapshot())
            # THE host sync, one per group — looked up through the module
            # so a counter wrapped around simulator.host_sync
            # (dispatch_bench style) sees sweep syncs too
            host = _sim.host_sync(metrics)
            tel_host = host.pop("_tel", None)     # (G, R, ...) leaves
            for g, (idx, _cell) in enumerate(members):
                hist = {"round": [], "acc": [], "mask_tpr": [], "mask_fpr": [],
                        "c1c2": []}
                for s, r in enumerate(eval_rounds):
                    _record_eval(hist, r,
                                 {k: v[g][s] for k, v in host.items()},
                                 log_every)
                hist["final_acc"] = hist["acc"][-1] if hist["acc"] \
                    else float("nan")
                hist["params"] = jax.tree.map(lambda x, g=g: x[g], params)
                # same flat comm keys as run_federated_training — cell
                # histories stay key- and value-identical to their solo twin
                d_model = sum(l.size // l.shape[0]
                              for l in jax.tree.leaves(params))
                cstats = _sim.comm_stats(_cell.cfg, d_model)
                hist.update(cstats)
                # the solo path records the fallback reason on the
                # history; cells must not lose it (ISSUE 8 satellite)
                hist["streaming_fallback"] = engine.streaming_fallback
                if tel_host is not None:
                    _sim.drain_round_telemetry(
                        fed.server,
                        {k: v[g] for k, v in tel_host.items()},
                        uplink_bytes=cstats["uplink_bytes_per_round"],
                        cell=idx)
                results[idx] = hist
    return results
