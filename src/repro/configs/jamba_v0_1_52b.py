"""jamba-v0.1-52b [arXiv:2403.19887] — hybrid Mamba+attention 1:7
interleave with MoE every other layer (16 experts top-2).
32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=65536, ssm_state=16."""
from ..models.config import ModelConfig

_GROUP = (("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"),
          ("mamba", "moe"), ("attn", "mlp"), ("mamba", "moe"),
          ("mamba", "mlp"), ("mamba", "moe"))

FULL = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14_336, vocab_size=65_536,
    layout=_GROUP,
    n_experts=16, top_k=2, n_shared_experts=0, d_expert=14_336,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    activation="swiglu",
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    n_layers=8, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    layout=_GROUP,
    n_experts=4, top_k=2, n_shared_experts=0, d_expert=256,
    ssm_state=8,
    activation="swiglu",
)
