"""gemma-2b [arXiv:2403.08295] — dense decoder, MQA (kv=1), GeGLU,
head_dim=256.  18L, d_model=2048, 8 heads, d_ff=16384, vocab=256000."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="gemma-2b",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256_000,
    layout=(("attn", "mlp"),),
    activation="geglu",
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512,
    layout=(("attn", "mlp"),),
    activation="geglu",
)
