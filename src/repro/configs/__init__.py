"""Architecture registry: the 10 assigned architectures (+ the paper's own
small models for the FL reproduction) as selectable configs.

Each module exposes ``FULL`` (the exact assigned configuration) and
``SMOKE`` (a reduced same-family variant: <=2-ish layers, d_model<=512,
<=4 experts) plus cites its source in the module docstring.
"""
from __future__ import annotations

import importlib

ARCHS = (
    "gemma_2b",
    "whisper_medium",
    "deepseek_moe_16b",
    "kimi_k2_1t_a32b",
    "h2o_danube_1_8b",
    "granite_20b",
    "llama_3_2_vision_90b",
    "jamba_v0_1_52b",
    "minitron_8b",
    "falcon_mamba_7b",
)

# CLI ids (dashes) -> module names
ARCH_IDS = {a.replace("_", "-"): a for a in ARCHS}
ARCH_IDS.update({
    "gemma-2b": "gemma_2b",
    "whisper-medium": "whisper_medium",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "granite-20b": "granite_20b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "minitron-8b": "minitron_8b",
    "falcon-mamba-7b": "falcon_mamba_7b",
})


def get(arch_id: str, smoke: bool = False):
    mod_name = ARCH_IDS.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.SMOKE if smoke else mod.FULL


def all_arch_ids():
    return [a.replace("_", "-") for a in ARCHS]
