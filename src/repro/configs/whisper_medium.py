"""whisper-medium [arXiv:2212.04356] — encoder-decoder audio backbone.
24+24L, d_model=1024, 16H (kv=16), d_ff=4096, vocab=51865, layernorm/GELU.
Mel+conv frontend is stubbed: the encoder consumes precomputed frame
embeddings (B, 1500, d_model)."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-medium",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51_865,
    layout=(("attn_x", "mlp"),),
    activation="gelu", norm="layernorm",
    n_enc_layers=24, enc_seq=1500,
    frontend="audio",
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    layout=(("attn_x", "mlp"),),
    activation="gelu", norm="layernorm",
    n_enc_layers=2, enc_seq=64,
    frontend="audio",
)
