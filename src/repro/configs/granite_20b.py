"""granite-20b [arXiv:2405.04324] — llama-arch code model with MQA.
52L, d_model=6144, 48H (kv=1), d_ff=24576, vocab=49152."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="granite-20b",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24_576, vocab_size=49_152,
    layout=(("attn", "mlp"),),
    activation="gelu",          # granite-20b-code uses gpt-bigcode-style MLP
)

SMOKE = ModelConfig(
    name="granite-20b-smoke",
    n_layers=2, d_model=192, n_heads=6, n_kv_heads=1,
    d_ff=384, vocab_size=512,
    layout=(("attn", "mlp"),),
    activation="gelu",
)
