"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision (90B scale)] —
VLM decoder: 100L (80 self + 20 gated cross-attn image layers, 1:4
interleave), d_model=8192, 64H (GQA kv=8), d_ff=28672, vocab=128256.
ViT/projector frontend stubbed: cross layers attend to precomputed patch
embeddings (B, 1600, d_model)."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-90b",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28_672, vocab_size=128_256,
    layout=(("attn", "mlp"), ("attn", "mlp"), ("attn", "mlp"),
            ("attn", "mlp"), ("xattn", "mlp")),
    activation="swiglu",
    frontend="vision", n_patches=1600,
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    n_layers=5, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    layout=(("attn", "mlp"), ("attn", "mlp"), ("attn", "mlp"),
            ("attn", "mlp"), ("xattn", "mlp")),
    activation="swiglu",
    frontend="vision", n_patches=64,
)
