"""falcon-mamba-7b [arXiv:2410.05355] — attention-free Mamba-1.
64 mamba blocks, d_model=4096 (d_inner=8192), ssm_state=16, vocab=65024."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65_024,
    layout=(("mamba", "none"),),
    ssm_state=16, ssm_conv=4, ssm_expand=2,
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke",
    n_layers=2, d_model=128, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=512,
    layout=(("mamba", "none"),),
    ssm_state=8,
)
