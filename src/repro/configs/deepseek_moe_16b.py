"""deepseek-moe-16b [arXiv:2401.06066] — fine-grained MoE: 64 routed
experts top-6 + 2 shared, expert hidden 1408; first layer dense.
28L, d_model=2048, 16H (kv=16), vocab=102400."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408 * 8,              # dense first-layer FFN (DeepSeek: ~d_ff dense)
    vocab_size=102_400,
    layout=(("attn", "moe"),), first_k_dense=1,
    n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
    activation="swiglu",
)

SMOKE = ModelConfig(
    name="deepseek-moe-16b-smoke",
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    layout=(("attn", "moe"),), first_k_dense=1,
    n_experts=4, top_k=2, n_shared_experts=1, d_expert=64,
    activation="swiglu",
)
