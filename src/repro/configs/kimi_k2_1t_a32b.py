"""kimi-k2-1t-a32b [arXiv:2501.kimi2] — trillion-parameter MoE:
384 routed experts top-8, 61L, d_model=7168, 64H (GQA kv=8),
expert hidden 2048, vocab=163840, first layer dense (paper-table entry)."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048 * 8,              # dense first-layer FFN
    vocab_size=163_840,
    layout=(("attn", "moe"),), first_k_dense=1,
    n_experts=384, top_k=8, n_shared_experts=1, d_expert=2048,
    activation="swiglu",
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke",
    n_layers=3, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=512,
    layout=(("attn", "moe"),), first_k_dense=1,
    n_experts=4, top_k=2, n_shared_experts=1, d_expert=64,
    activation="swiglu",
)
