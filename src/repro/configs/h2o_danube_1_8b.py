"""h2o-danube-1.8b [arXiv:2401.16818] — llama+mistral mix with sliding
window attention.  24L, d_model=2560, 32H (GQA kv=8), d_ff=6912,
vocab=32000, window=4096 (mistral-style SWA -> long_500k capable)."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-1.8b",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab_size=32_000,
    layout=(("swa", "mlp"),), window=4096,
    activation="swiglu",
)

SMOKE = ModelConfig(
    name="h2o-danube-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    layout=(("swa", "mlp"),), window=16,
    activation="swiglu",
)
