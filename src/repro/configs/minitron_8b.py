"""minitron-8b [arXiv:2407.14679] — pruned Nemotron-4.
32L, d_model=4096, 32H (GQA kv=8), d_ff=16384, vocab=256000."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="minitron-8b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16_384, vocab_size=256_000,
    layout=(("attn", "mlp"),),
    activation="relu",          # nemotron uses squared-relu; relu^2 ~ relu here
)

SMOKE = ModelConfig(
    name="minitron-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    layout=(("attn", "mlp"),),
    activation="relu",
)
