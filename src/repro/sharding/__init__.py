from .api import (MODEL_AXIS, DATA_AXES, POD_AXIS, ShardMismatchError,
                  get_mesh, set_mesh, use_mesh, shard,
                  client_spec, client_sharding, client_put, shard_clients,
                  data_shard_count, pod_count, pod_data_counts,
                  lane_spec, shard_lanes, put_clients_by_shard,
                  param_partition_spec, partition_pytree,
                  sweep_put)
