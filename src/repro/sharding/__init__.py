from .api import (MODEL_AXIS, DATA_AXES, get_mesh, set_mesh, use_mesh, shard,
                  param_partition_spec, partition_pytree)
