from .api import (MODEL_AXIS, DATA_AXES, get_mesh, set_mesh, use_mesh, shard,
                  client_spec, client_sharding, client_put, shard_clients,
                  data_shard_count, param_partition_spec, partition_pytree,
                  sweep_put)
