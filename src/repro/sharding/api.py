"""Sharding utilities.

The production mesh has axes ``("pod", "data", "model")`` (multi-pod) or
``("data", "model")`` (single pod).  FL clients live on the (pod, data)
axes; tensor/expert parallelism lives on ``model``.

Model code only ever constrains the ``model`` axis (via :func:`shard`),
because the FL round step runs inside ``jax.shard_map`` that is *manual*
over the client axes and *auto* over ``model`` — constraints that name a
manual axis would be rejected there.  Batch/client sharding is applied by
the launcher on the function boundary instead.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"
POD_AXIS = "pod"
DATA_AXES = ("pod", "data")  # whichever exist in the active mesh

_state = threading.local()


class ShardMismatchError(ValueError):
    """A requested shard/pod count cannot tile the axis it partitions.

    Raised with the offending numbers *named* (count, block count, the
    chunking that produced it) instead of surfacing as a reshape failure
    deep inside a traced fold — the error a user can actually act on
    (pick a ``client_chunk`` so the padded block count tiles, or drop
    the forced count and let the mesh-derived auto value clamp)."""


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def shard(x, spec: P):
    """Constrain ``x`` to ``spec`` when a mesh is active; no-op otherwise.

    ``spec`` must only reference the ``model`` axis (see module docstring).
    Inside ``shard_map`` the context mesh carries Manual axis types for the
    client axes, so the constraint must be built against the *abstract*
    mesh from the trace context, not the concrete Auto-typed mesh.
    """
    mesh = get_mesh()
    if mesh is None or MODEL_AXIS not in mesh.axis_names:
        return x
    # skip constraints that cannot tile: forcing e.g. 8 heads onto a 16-way
    # model axis makes the SPMD partitioner fall back to full
    # rematerialization (replicate + repartition) — worse than no hint.
    for dim, name in zip(x.shape, spec):
        if name is None:
            continue
        names = name if isinstance(name, tuple) else (name,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if dim % size != 0:
            return x
    am = jax.sharding.get_abstract_mesh()
    if am is not None and not am.empty and MODEL_AXIS in am.axis_names:
        return jax.lax.with_sharding_constraint(x, NamedSharding(am, spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------------------
# Client-axis sharding (the federated round engine's contract).
#
# The simulator-side round engine (fl/engine.py) carries the federation
# as stacked arrays with a leading client axis — minibatch stacks,
# (N, D) update/guide matrices.  When a mesh is active that axis is
# sharded over the data axes, mirroring how launch/train.py places one
# client per (pod, data) coordinate; without a mesh (or when the axis
# does not tile) every helper is a no-op so the single-device path is
# untouched.
# ----------------------------------------------------------------------

def _client_axes_in(mesh) -> tuple:
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def client_spec(ndim: int, axis: int = 0, mesh: Optional[Mesh] = None):
    """PartitionSpec placing dim ``axis`` (the client axis) on the mesh's
    data axes; None when no mesh / no data axes are available.

    On a multi-pod mesh the spec names the ``("pod", "data")`` *pair*,
    which XLA tiles pod-major: client ``c`` of ``C`` lands on pod
    ``c // (C / pods)`` — contiguous client ranges per pod.  That is the
    **pod-major client layout contract** (DESIGN.md §9): the two-tier
    streaming fold's pod groups (fl/streaming.py) partition the block
    axis into the same contiguous ranges, so "the clients a pod folds"
    and "the clients a pod's devices hold" are the same set."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return None
    caxes = _client_axes_in(mesh)
    if not caxes:
        return None
    spec = [None] * ndim
    spec[axis] = caxes if len(caxes) > 1 else caxes[0]
    return P(*spec)


def client_sharding(ndim: int, axis: int = 0,
                    mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    """NamedSharding for a client-stacked array (None when inapplicable)."""
    mesh = mesh if mesh is not None else get_mesh()
    spec = client_spec(ndim, axis, mesh)
    return None if spec is None else NamedSharding(mesh, spec)


def _client_axis_size(mesh) -> int:
    size = 1
    for a in _client_axes_in(mesh):
        size *= mesh.shape[a]
    return size


def data_shard_count(mesh: Optional[Mesh] = None) -> int:
    """How many ways the active mesh splits the client axis — the
    **product over every DATA_AXES member present** in the mesh (a
    multi-pod mesh counts ``pod x data``, a single-pod mesh just
    ``data``), which is the natural total lane count for the streaming
    fold's tree-reduce (fl/streaming.py).  1 without a mesh or without
    data axes, so the no-mesh path degrades to the sequential sweep."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return 1
    return _client_axis_size(mesh)


def pod_count(mesh: Optional[Mesh] = None) -> int:
    """Size of the mesh's ``pod`` axis — the auto tier count for the
    hierarchical streaming fold (fl/streaming.py, DESIGN.md §9).  1
    without a mesh or on a single-pod mesh, so the two-tier path
    degrades to the flat single-tier fold."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None or POD_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[POD_AXIS]


def pod_data_counts(mesh: Optional[Mesh] = None):
    """``(pods, per_pod_shards)`` of the active mesh: the pod-axis size
    and the product of the remaining data axes.  ``pods *
    per_pod_shards == data_shard_count`` always — the two-tier fold
    reorganizes the same lanes into a two-level merge, it never changes
    how many there are."""
    mesh = mesh if mesh is not None else get_mesh()
    p = pod_count(mesh)
    return p, data_shard_count(mesh) // p


def lane_spec(ndim: int, mesh: Optional[Mesh] = None):
    """PartitionSpec for the two-tier fold's lane tensor: dim 0 (the pod
    group axis) on ``pod``, dim 1 (the within-pod shard axis) on
    ``data`` — pod-local folds stay inside their pod's devices and only
    the O(pods·D) partial AggStates cross the interconnect.  None when
    the mesh has no data axes; on a pod-less mesh dim 1 alone is
    placed."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None or ndim < 2:
        return None
    has_pod = POD_AXIS in mesh.axis_names
    caxes = _client_axes_in(mesh)
    if not caxes:
        return None
    spec = [None] * ndim
    if has_pod:
        spec[0] = POD_AXIS
        rest = tuple(a for a in caxes if a != POD_AXIS)
        if rest:
            spec[1] = rest if len(rest) > 1 else rest[0]
    else:
        spec[1] = caxes if len(caxes) > 1 else caxes[0]
    return P(*spec)


def shard_lanes(x):
    """Constrain a ``(pods, shards, ...)`` fold-lane tensor over the
    ``("pod", "data")`` axes (traced code) — :func:`shard_clients`'s
    two-axis twin, with the same degrade-gracefully contract: no-op
    without a mesh, without data axes, or when a lane dim does not tile
    its mesh axis."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = lane_spec(x.ndim, mesh)
    if spec is None:
        return x
    for dim, name in zip(x.shape, spec):
        if name is None:
            continue
        names = name if isinstance(name, tuple) else (name,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if dim % size != 0:
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def put_clients_by_shard(build_fn, shape, axis: int = 0,
                         mesh: Optional[Mesh] = None):
    """Assemble a client-stacked array **one shard at a time**.

    ``build_fn(lo, hi)`` produces rows ``[lo, hi)`` of client axis
    ``axis`` (full size on every other dim).  Each shard of the client
    sharding is built independently, placed directly on its device, and
    the global array is assembled with
    ``jax.make_array_from_single_device_arrays`` — no single host
    buffer ever holds the full ``shape`` stack, which is what lets a
    multi-pod federation stage per-pod batch stacks whose *union*
    exceeds one host's memory (data/pipeline.py, DESIGN.md §9).

    Degrades to ``client_put(build_fn(0, C))`` — one full host build —
    without a mesh or when the client axis does not tile it."""
    mesh = mesh if mesh is not None else get_mesh()
    C = shape[axis]
    sharding = client_sharding(len(shape), axis, mesh)
    if sharding is None or C % _client_axis_size(mesh) != 0:
        return client_put(build_fn(0, C), axis)
    arrays, built = [], {}   # model-axis replicas share one build
    for dev, idx in sharding.addressable_devices_indices_map(
            tuple(shape)).items():
        sl = idx[axis]
        lo = 0 if sl.start is None else int(sl.start)
        hi = C if sl.stop is None else int(sl.stop)
        if (lo, hi) not in built:
            built[(lo, hi)] = build_fn(lo, hi)
        arrays.append(jax.device_put(built[(lo, hi)], dev))
    return jax.make_array_from_single_device_arrays(
        tuple(shape), sharding, arrays)


def shard_clients(x, axis: int = 0):
    """Constrain dim ``axis`` of ``x`` over the data axes (traced code).

    No-op without a mesh, without data axes, or when the dim does not
    tile — the same degrade-gracefully contract as :func:`shard`.
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    caxes = _client_axes_in(mesh)
    if not caxes or x.shape[axis] % _client_axis_size(mesh) != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, client_spec(x.ndim, axis, mesh)))


def client_put(x, axis: int = 0):
    """Place a host-built client-stacked array with the client sharding
    (eager twin of :func:`shard_clients`, for per-segment batch stacks)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    if x.shape[axis] % _client_axis_size(mesh) != 0:
        return x
    s = client_sharding(x.ndim, axis, mesh)
    return x if s is None else jax.device_put(x, s)


def sweep_put(tree):
    """Place a sweep group's stacked operands (leading *scenario* axis on
    every leaf) over the mesh's data axes — one batch of runs per data
    coordinate, the sweep engine's placement contract (fl/sweep.py,
    DESIGN.md §8).

    The scenario axis reuses the client-axis machinery with ``axis=0``:
    independent runs are embarrassingly parallel, so they occupy the
    same mesh axes a single run's client axis would.  Degrades per-leaf
    to a no-op without a mesh, without data axes, or when the group
    size does not tile the data-axis size — a partial group still runs,
    just without cross-device parallelism for the remainder.  Inside
    the batched program the per-run client-axis constraints
    (:func:`shard_clients`) no-op whenever the *per-cell* client axis
    does not tile the mesh, so placing the scenario axis here is what
    decides the layout; pick group sizes divisible by
    :func:`data_shard_count` to keep cells device-aligned."""
    return jax.tree.map(lambda x: client_put(x, axis=0), tree)


# ----------------------------------------------------------------------
# Parameter partition rules (megatron-style + expert parallel).
# Keyed on substrings of the flattened parameter path.
# ----------------------------------------------------------------------
_RULES = (
    # (path substring, spec builder(ndim))
    ("embed",          lambda nd: _last(nd, None, over_first=True)),   # (V, D): shard V
    ("lm_head",        lambda nd: _last(nd, MODEL_AXIS)),              # (D, V): shard V
    ("wq",             lambda nd: _last(nd, MODEL_AXIS)),              # (D, H*dh)
    ("wk",             lambda nd: _last(nd, MODEL_AXIS)),
    ("wv",             lambda nd: _last(nd, MODEL_AXIS)),
    ("wo",             lambda nd: _secondlast(nd, MODEL_AXIS)),        # (H*dh, D)
    ("w_up",           lambda nd: _last(nd, MODEL_AXIS)),              # (D, F)
    ("w_gate",         lambda nd: _last(nd, MODEL_AXIS)),
    ("w_down",         lambda nd: _secondlast(nd, MODEL_AXIS)),        # (F, D)
    ("router",         lambda nd: _last(nd, None)),
    ("routed",         lambda nd: _expert(nd)),                        # (..., E, D, F): shard E
    ("shared",         lambda nd: _last(nd, MODEL_AXIS)),
    ("in_proj",        lambda nd: _last(nd, MODEL_AXIS)),              # mamba (D, 2*d_inner)
    ("conv_w",         lambda nd: _last(nd, MODEL_AXIS)),              # (k, d_inner)
    ("conv_b",         lambda nd: _last(nd, MODEL_AXIS)),
    ("x_proj",         lambda nd: _secondlast(nd, MODEL_AXIS)),        # (d_inner, R+2S)
    ("dt_proj",        lambda nd: _last(nd, MODEL_AXIS)),              # (R, d_inner)
    ("A_log",          lambda nd: _secondlast(nd, MODEL_AXIS)),        # (d_inner, S)
    ("D_skip",         lambda nd: _last(nd, MODEL_AXIS)),              # (d_inner,)
    ("dt_bias",        lambda nd: _last(nd, MODEL_AXIS)),
    ("out_proj",       lambda nd: _secondlast(nd, MODEL_AXIS)),        # (d_inner, D)
)


def _last(nd, axis, over_first=False):
    spec = [None] * nd
    if over_first:
        spec[-2 if nd >= 2 else 0] = MODEL_AXIS   # embed (.., V, D) -> shard V
    else:
        spec[-1] = axis
    return P(*spec)


def _secondlast(nd, axis):
    spec = [None] * nd
    if nd >= 2:
        spec[-2] = axis
    else:
        spec[-1] = axis
    return P(*spec)


def _expert(nd):
    # routed expert weights are (n_groups?, E, D, F) — shard the expert dim.
    spec = [None] * nd
    spec[-3 if nd >= 3 else 0] = MODEL_AXIS
    return P(*spec)


def param_partition_spec(path: str, ndim: int) -> P:
    for key, builder in _RULES:
        if key in path:
            return builder(ndim)
    return P()  # norms, biases, scalars: replicated


def partition_pytree(params):
    """Map a parameter pytree to a pytree of PartitionSpecs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        specs.append(param_partition_spec(key, leaf.ndim))
    return jax.tree_util.tree_unflatten(treedef, specs)
