"""Sharding utilities.

The production mesh has axes ``("pod", "data", "model")`` (multi-pod) or
``("data", "model")`` (single pod).  FL clients live on the (pod, data)
axes; tensor/expert parallelism lives on ``model``.

Model code only ever constrains the ``model`` axis (via :func:`shard`),
because the FL round step runs inside ``jax.shard_map`` that is *manual*
over the client axes and *auto* over ``model`` — constraints that name a
manual axis would be rejected there.  Batch/client sharding is applied by
the launcher on the function boundary instead.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"
POD_AXIS = "pod"
DATA_AXES = ("pod", "data")  # whichever exist in the active mesh

_state = threading.local()


class ShardMismatchError(ValueError):
    """A requested shard/pod count cannot tile the axis it partitions.

    Raised with the offending numbers *named* (count, block count, the
    chunking that produced it) instead of surfacing as a reshape failure
    deep inside a traced fold — the error a user can actually act on
    (pick a ``client_chunk`` so the padded block count tiles, or drop
    the forced count and let the mesh-derived auto value clamp)."""


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def _abstract_mesh():
    """The trace context's abstract mesh, or None on JAX versions
    without one (older releases build constraints from the concrete
    mesh directly, which is also what an empty abstract mesh means)."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    return None if fn is None else fn()


def shard(x, spec: P):
    """Constrain ``x`` to ``spec`` when a mesh is active; no-op otherwise.

    ``spec`` must only reference the ``model`` axis (see module docstring).
    Inside ``shard_map`` the context mesh carries Manual axis types for the
    client axes, so the constraint must be built against the *abstract*
    mesh from the trace context, not the concrete Auto-typed mesh.
    """
    mesh = get_mesh()
    if mesh is None or MODEL_AXIS not in mesh.axis_names:
        return x
    # skip constraints that cannot tile: forcing e.g. 8 heads onto a 16-way
    # model axis makes the SPMD partitioner fall back to full
    # rematerialization (replicate + repartition) — worse than no hint.
    for dim, name in zip(x.shape, spec):
        if name is None:
            continue
        names = name if isinstance(name, tuple) else (name,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if dim % size != 0:
            return x
    am = _abstract_mesh()
    if am is not None and not am.empty and MODEL_AXIS in am.axis_names:
        return jax.lax.with_sharding_constraint(x, NamedSharding(am, spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------------------
# Client-axis sharding (the federated round engine's contract).
#
# The simulator-side round engine (fl/engine.py) carries the federation
# as stacked arrays with a leading client axis — minibatch stacks,
# (N, D) update/guide matrices.  When a mesh is active that axis is
# sharded over the data axes, mirroring how launch/train.py places one
# client per (pod, data) coordinate; without a mesh (or when the axis
# does not tile) every helper is a no-op so the single-device path is
# untouched.
# ----------------------------------------------------------------------

def _client_axes_in(mesh) -> tuple:
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def client_spec(ndim: int, axis: int = 0, mesh: Optional[Mesh] = None):
    """PartitionSpec placing dim ``axis`` (the client axis) on the mesh's
    data axes; None when no mesh / no data axes are available.

    On a multi-pod mesh the spec names the ``("pod", "data")`` *pair*,
    which XLA tiles pod-major: client ``c`` of ``C`` lands on pod
    ``c // (C / pods)`` — contiguous client ranges per pod.  That is the
    **pod-major client layout contract** (DESIGN.md §9): the two-tier
    streaming fold's pod groups (fl/streaming.py) partition the block
    axis into the same contiguous ranges, so "the clients a pod folds"
    and "the clients a pod's devices hold" are the same set."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return None
    caxes = _client_axes_in(mesh)
    if not caxes:
        return None
    spec = [None] * ndim
    spec[axis] = caxes if len(caxes) > 1 else caxes[0]
    return P(*spec)


def client_sharding(ndim: int, axis: int = 0,
                    mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    """NamedSharding for a client-stacked array (None when inapplicable)."""
    mesh = mesh if mesh is not None else get_mesh()
    spec = client_spec(ndim, axis, mesh)
    return None if spec is None else NamedSharding(mesh, spec)


def _client_axis_size(mesh) -> int:
    size = 1
    for a in _client_axes_in(mesh):
        size *= mesh.shape[a]
    return size


def data_shard_count(mesh: Optional[Mesh] = None) -> int:
    """How many ways the active mesh splits the client axis — the
    **product over every DATA_AXES member present** in the mesh (a
    multi-pod mesh counts ``pod x data``, a single-pod mesh just
    ``data``), which is the natural total lane count for the streaming
    fold's tree-reduce (fl/streaming.py).  1 without a mesh or without
    data axes, so the no-mesh path degrades to the sequential sweep."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return 1
    return _client_axis_size(mesh)


def pod_count(mesh: Optional[Mesh] = None) -> int:
    """Size of the mesh's ``pod`` axis — the auto tier count for the
    hierarchical streaming fold (fl/streaming.py, DESIGN.md §9).  1
    without a mesh or on a single-pod mesh, so the two-tier path
    degrades to the flat single-tier fold."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None or POD_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[POD_AXIS]


def pod_data_counts(mesh: Optional[Mesh] = None):
    """``(pods, per_pod_shards)`` of the active mesh: the pod-axis size
    and the product of the remaining data axes.  ``pods *
    per_pod_shards == data_shard_count`` always — the two-tier fold
    reorganizes the same lanes into a two-level merge, it never changes
    how many there are."""
    mesh = mesh if mesh is not None else get_mesh()
    p = pod_count(mesh)
    return p, data_shard_count(mesh) // p


def lane_spec(ndim: int, mesh: Optional[Mesh] = None):
    """PartitionSpec for the two-tier fold's lane tensor: dim 0 (the pod
    group axis) on ``pod``, dim 1 (the within-pod shard axis) on
    ``data`` — pod-local folds stay inside their pod's devices and only
    the O(pods·D) partial AggStates cross the interconnect.  None when
    the mesh has no data axes; on a pod-less mesh dim 1 alone is
    placed."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None or ndim < 2:
        return None
    has_pod = POD_AXIS in mesh.axis_names
    caxes = _client_axes_in(mesh)
    if not caxes:
        return None
    spec = [None] * ndim
    if has_pod:
        spec[0] = POD_AXIS
        rest = tuple(a for a in caxes if a != POD_AXIS)
        if rest:
            spec[1] = rest if len(rest) > 1 else rest[0]
    else:
        spec[1] = caxes if len(caxes) > 1 else caxes[0]
    return P(*spec)


def shard_lanes(x):
    """Constrain a ``(pods, shards, ...)`` fold-lane tensor over the
    ``("pod", "data")`` axes (traced code) — :func:`shard_clients`'s
    two-axis twin, with the same degrade-gracefully contract: no-op
    without a mesh, without data axes, or when a lane dim does not tile
    its mesh axis."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = lane_spec(x.ndim, mesh)
    if spec is None:
        return x
    for dim, name in zip(x.shape, spec):
        if name is None:
            continue
        names = name if isinstance(name, tuple) else (name,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if dim % size != 0:
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def put_clients_by_shard(build_fn, shape, axis: int = 0,
                         mesh: Optional[Mesh] = None):
    """Assemble a client-stacked array **one shard at a time**.

    ``build_fn(lo, hi)`` produces rows ``[lo, hi)`` of client axis
    ``axis`` (full size on every other dim).  Each shard of the client
    sharding is built independently, placed directly on its device, and
    the global array is assembled with
    ``jax.make_array_from_single_device_arrays`` — no single host
    buffer ever holds the full ``shape`` stack, which is what lets a
    multi-pod federation stage per-pod batch stacks whose *union*
    exceeds one host's memory (data/pipeline.py, DESIGN.md §9).

    Degrades to ``client_put(build_fn(0, C))`` — one full host build —
    without a mesh or when the client axis does not tile it."""
    mesh = mesh if mesh is not None else get_mesh()
    C = shape[axis]
    sharding = client_sharding(len(shape), axis, mesh)
    if sharding is None or C % _client_axis_size(mesh) != 0:
        return client_put(build_fn(0, C), axis)
    arrays, built = [], {}   # model-axis replicas share one build
    for dev, idx in sharding.addressable_devices_indices_map(
            tuple(shape)).items():
        sl = idx[axis]
        lo = 0 if sl.start is None else int(sl.start)
        hi = C if sl.stop is None else int(sl.stop)
        if (lo, hi) not in built:
            built[(lo, hi)] = build_fn(lo, hi)
        arrays.append(jax.device_put(built[(lo, hi)], dev))
    return jax.make_array_from_single_device_arrays(
        tuple(shape), sharding, arrays)


def shard_clients(x, axis: int = 0):
    """Constrain dim ``axis`` of ``x`` over the data axes (traced code).

    No-op without a mesh, without data axes, or when the dim does not
    tile — the same degrade-gracefully contract as :func:`shard`.
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    caxes = _client_axes_in(mesh)
    if not caxes or x.shape[axis] % _client_axis_size(mesh) != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, client_spec(x.ndim, axis, mesh)))


def client_put(x, axis: int = 0):
    """Place a host-built client-stacked array with the client sharding
    (eager twin of :func:`shard_clients`, for per-segment batch stacks)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    if x.shape[axis] % _client_axis_size(mesh) != 0:
        return x
    s = client_sharding(x.ndim, axis, mesh)
    return x if s is None else jax.device_put(x, s)


def sweep_put(tree):
    """Place a sweep group's stacked operands (leading *scenario* axis on
    every leaf) over the mesh's data axes — one batch of runs per data
    coordinate, the sweep engine's placement contract (fl/sweep.py,
    DESIGN.md §8).

    The scenario axis reuses the client-axis machinery with ``axis=0``:
    independent runs are embarrassingly parallel, so they occupy the
    same mesh axes a single run's client axis would.  Degrades per-leaf
    to a no-op without a mesh, without data axes, or when the group
    size does not tile the data-axis size — a partial group still runs,
    just without cross-device parallelism for the remainder.  Inside
    the batched program the per-run client-axis constraints
    (:func:`shard_clients`) no-op whenever the *per-cell* client axis
    does not tile the mesh, so placing the scenario axis here is what
    decides the layout; pick group sizes divisible by
    :func:`data_shard_count` to keep cells device-aligned."""
    return jax.tree.map(lambda x: client_put(x, axis=0), tree)


# ----------------------------------------------------------------------
# Client x model 2D sharding (the tensor-sharded round contract).
#
# When the mesh also carries a non-trivial ``model`` axis, the engine's
# flattened per-client quantities — the (N, D)/(chunk, D) update and
# guide matrices, the (D,) AggState numerator and round delta — shard
# their *last* dim (the flat model dim D) over ``model`` while the
# client dim keeps the (pod, data) placement above.  Every helper
# degrades per-dim: a dim that does not tile its mesh axes is simply
# left unconstrained, so the no-mesh / model=1 paths trace the same
# program as ever (DESIGN.md §12).
# ----------------------------------------------------------------------

def model_shard_count(mesh: Optional[Mesh] = None) -> int:
    """How many ways the active mesh splits the flat model dim — the
    size of the ``model`` axis; 1 without a mesh or without the axis,
    so callers can gate model-sharded work on ``> 1``."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None or MODEL_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[MODEL_AXIS]


def update_spec(ndim: int, axis: int = 0,
                mesh: Optional[Mesh] = None) -> Optional[P]:
    """PartitionSpec for a client-stacked *flattened* quantity: dim
    ``axis`` (clients) over the data axes, the last dim (flat D) over
    ``model``.  For 1-D inputs (a lone (D,) vector — AggState, delta)
    only the model placement applies.  None when the mesh constrains
    neither dim."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return None
    spec = [None] * ndim
    caxes = _client_axes_in(mesh)
    if caxes and ndim > 1 and axis != ndim - 1:
        spec[axis] = caxes if len(caxes) > 1 else caxes[0]
    if MODEL_AXIS in mesh.axis_names and mesh.shape[MODEL_AXIS] > 1:
        spec[-1] = MODEL_AXIS
    if all(s is None for s in spec):
        return None
    return P(*spec)


def _tiling_spec(x, spec: P, mesh) -> Optional[P]:
    """Drop every spec entry whose dim does not tile its mesh axes; None
    when nothing survives (the degrade-gracefully contract, per-dim)."""
    out, any_named = [], False
    for dim, name in zip(x.shape, spec):
        if name is None:
            out.append(None)
            continue
        names = name if isinstance(name, tuple) else (name,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if dim % size != 0:
            out.append(None)
        else:
            out.append(name)
            any_named = True
    return P(*out) if any_named else None


def shard_flat(x):
    """Constrain a flattened model-dim quantity over the ``model`` axis —
    for the O(D) streaming AggState, the round delta, and the root
    update.  Two layouts: a rank-1 ``(D,)`` vector tiles its last dim
    (the legacy contract), while the rank-2 **blocked** layout
    ``(ms, L)`` built by :func:`ravel_sharded` places ``model`` on the
    row dim and leaves the column dim unsharded.  No-op without a mesh,
    with a trivial model axis, or when the dim does not tile."""
    mesh = get_mesh()
    if mesh is None or model_shard_count(mesh) <= 1:
        return x
    if x.ndim == 2 and x.shape[0] == model_shard_count(mesh):
        spec = P(MODEL_AXIS, None)
    else:
        spec = _tiling_spec(
            x, P(*([None] * (x.ndim - 1) + [MODEL_AXIS])), mesh)
    if spec is None:
        return x
    am = _abstract_mesh()
    if am is not None and not am.empty and MODEL_AXIS in am.axis_names:
        return jax.lax.with_sharding_constraint(x, NamedSharding(am, spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_updates(x, axis: int = 0):
    """Constrain a flattened client-stacked matrix over *both* mesh
    families: dim ``axis`` (clients) on the data axes AND the last dim
    (flat D) on ``model`` — :func:`shard_clients` composed with
    :func:`shard_flat` as ONE constraint (two sequential constraints
    would each override the other's spec).  Per-dim degrade: either
    placement drops independently when its dim does not tile, and with
    no model axis this is exactly ``shard_clients``."""
    mesh = get_mesh()
    if mesh is None:
        return x
    ms = model_shard_count(mesh)
    if x.ndim == 3 and ms > 1 and x.shape[1] == ms:
        # blocked layout (clients, ms, L) from flatten_updates_sharded:
        # model on the row dim, columns unsharded.
        caxes = _client_axes_in(mesh)
        csize = 1
        for a in caxes:
            csize *= mesh.shape[a]
        cspec = None
        if caxes and x.shape[0] % csize == 0:
            cspec = caxes if len(caxes) > 1 else caxes[0]
        spec = P(cspec, MODEL_AXIS, None)
    else:
        spec = update_spec(x.ndim, axis, mesh)
        if spec is None:
            return x
        spec = _tiling_spec(x, spec, mesh)
        if spec is None:
            return x
    am = _abstract_mesh()
    if (am is not None and not am.empty
            and all(n in am.axis_names for e in spec if e is not None
                    for n in (e if isinstance(e, tuple) else (e,)))):
        return jax.lax.with_sharding_constraint(x, NamedSharding(am, spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _leaf_plan(path, shape, ms: int):
    """``(shape, size, cols, split_dim)`` for one leaf of the blocked
    layout.  ``split_dim`` is the dim the MODEL_AXIS partition table
    shards for this leaf (when it tiles ``ms``) — rows then follow the
    device tiling, so the blocked build is shard-local; ``None`` picks
    the row-major pad-and-split fallback for replicated leaves."""
    import math as _math
    key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                   for p in path)
    sz = int(_math.prod(shape))
    for k, name in enumerate(param_partition_spec(key, len(shape))):
        if name == MODEL_AXIS and shape[k] % ms == 0:
            return shape, sz, sz // ms, k
    return shape, sz, -(-sz // ms), None


def flatten_updates_sharded(updates):
    """Model-sharded twin of ``core.aggregators.flatten_updates``: the
    same per-element fp32 casts in the same leaf order, but laid out as
    a **shard-aligned blocked matrix** ``(N, ms, L)`` instead of the
    flat ``(N, D)`` — ``ms = model_shard_count()`` rows, ``L = Σ_ℓ
    ceil(n_ℓ/ms)`` columns, sharded ``P(data, model, None)``.

    Why not just tile ``(N, D)`` over ``model``?  GSPMD cannot run a
    concatenate shard-local when the output is sharded along the
    concatenated dim (leaf boundaries don't align with shard
    boundaries), and it all-gathers every ``dynamic_update_slice``
    along a sharded dim — either build materializes the full unsharded
    D as an XLA temp (~400 MB per buffer at 100M params).  The blocked
    layout concatenates along the *unsharded* column dim: each leaf is
    raveled, zero-padded to a multiple of ``ms``, folded into ``ms``
    rows, and the concat runs shard-local while the per-leaf reshape
    lowers to one slice per shard.  Peak extra memory is one leaf, not
    D (DESIGN.md §12, benchmarks/model_fl_bench).

    Row assignment is **tiling-aligned**: a leaf whose partition-table
    spec shards dim ``k`` over ``model`` is split along dim ``k`` into
    its ``ms`` device tiles — row ``s`` holds exactly the elements
    device ``s`` already owns, so building the blocked matrix from
    tensor-sharded gradients is a pure local reshape (no per-leaf
    all-gather).  Unsharded leaves (biases, norms, non-tiling dims)
    fall back to a row-major split of the raveled leaf, zero-padded to
    a multiple of ``ms`` — they are the small ones.

    Element values are bitwise those of the flat build modulo
    arrangement (padding elements are zeros that never reach the model:
    ``unravel`` trims them).  Callers gate on ``model_shard_count() >
    1``, so the trivial-model-axis jaxpr stays byte-identical to the
    historical flat path."""
    ms = model_shard_count()
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(updates)
    plans = [_leaf_plan(path, u.shape[1:], ms) for path, u in flat_p]
    leaves = [u for _, u in flat_p]
    n = leaves[0].shape[0]

    pieces = []
    for u, (shape, sz, c, k) in zip(leaves, plans):
        uf = u.astype(jnp.float32)
        if k is not None:
            nk = shape[k]
            uf = uf.reshape((n,) + shape[:k] + (ms, nk // ms)
                            + shape[k + 1:])
            uf = jnp.moveaxis(uf, 1 + k, 1)
            pieces.append(uf.reshape(n, ms, c))
        else:
            p = uf.reshape(n, sz)
            if c * ms != sz:
                p = jnp.pad(p, ((0, 0), (0, c * ms - sz)))
            pieces.append(p.reshape(n, ms, c))
    flat = shard_updates(jnp.concatenate(pieces, axis=2))

    def unravel(vec):
        # vec: (ms, L) — slice each leaf's column band and invert its
        # row assignment (tile order for sharded leaves, row-major +
        # pad trim for the rest).
        outs, o = [], 0
        for shape, sz, c, k in plans:
            band = vec[:, o:o + c]
            if k is not None:
                nk = shape[k]
                band = band.reshape((ms,) + shape[:k] + (nk // ms,)
                                    + shape[k + 1:])
                band = jnp.moveaxis(band, 0, k)
                outs.append(band.reshape(shape))
            else:
                outs.append(band.reshape(ms * c)[:sz].reshape(shape))
            o += c
        return jax.tree.unflatten(treedef, outs)
    return flat, unravel


def ravel_sharded(tree):
    """One-client :func:`flatten_updates_sharded`: ravel a pytree into
    the blocked ``(ms, L)`` fp32 layout, sharded ``P(model, None)`` —
    the enclave's per-guide flattening and the fltrust root at zoo
    scale.  Same column offsets and row assignment as the
    client-stacked builder, so guides and updates align
    element-for-element."""
    ms = model_shard_count()
    flat_p, _ = jax.tree_util.tree_flatten_with_path(tree)
    pieces = []
    for path, u in flat_p:
        shape, sz, c, k = _leaf_plan(path, u.shape, ms)
        uf = u.astype(jnp.float32)
        if k is not None:
            nk = shape[k]
            uf = uf.reshape(shape[:k] + (ms, nk // ms) + shape[k + 1:])
            uf = jnp.moveaxis(uf, k, 0)
            pieces.append(uf.reshape(ms, c))
        else:
            p = uf.reshape(sz)
            if c * ms != sz:
                p = jnp.pad(p, (0, c * ms - sz))
            pieces.append(p.reshape(ms, c))
    return shard_flat(jnp.concatenate(pieces, axis=1))


# ----------------------------------------------------------------------
# Parameter partition rules (megatron-style + expert parallel).
# Keyed on substrings of the flattened parameter path.
# ----------------------------------------------------------------------
_RULES = (
    # (path substring, spec builder(ndim))
    ("embed",          lambda nd: _last(nd, None, over_first=True)),   # (V, D): shard V
    ("lm_head",        lambda nd: _last(nd, MODEL_AXIS)),              # (D, V): shard V
    ("wq",             lambda nd: _last(nd, MODEL_AXIS)),              # (D, H*dh)
    ("wk",             lambda nd: _last(nd, MODEL_AXIS)),
    ("wv",             lambda nd: _last(nd, MODEL_AXIS)),
    ("wo",             lambda nd: _secondlast(nd, MODEL_AXIS)),        # (H*dh, D)
    ("w_up",           lambda nd: _last(nd, MODEL_AXIS)),              # (D, F)
    ("w_gate",         lambda nd: _last(nd, MODEL_AXIS)),
    ("w_down",         lambda nd: _secondlast(nd, MODEL_AXIS)),        # (F, D)
    ("router",         lambda nd: _last(nd, None)),
    ("routed",         lambda nd: _expert(nd)),                        # (..., E, D, F): shard E
    ("shared",         lambda nd: _last(nd, MODEL_AXIS)),
    ("in_proj",        lambda nd: _last(nd, MODEL_AXIS)),              # mamba (D, 2*d_inner)
    ("conv_w",         lambda nd: _last(nd, MODEL_AXIS)),              # (k, d_inner)
    ("conv_b",         lambda nd: _last(nd, MODEL_AXIS)),
    ("x_proj",         lambda nd: _secondlast(nd, MODEL_AXIS)),        # (d_inner, R+2S)
    ("dt_proj",        lambda nd: _last(nd, MODEL_AXIS)),              # (R, d_inner)
    ("A_log",          lambda nd: _secondlast(nd, MODEL_AXIS)),        # (d_inner, S)
    ("D_skip",         lambda nd: _last(nd, MODEL_AXIS)),              # (d_inner,)
    ("dt_bias",        lambda nd: _last(nd, MODEL_AXIS)),
    ("out_proj",       lambda nd: _secondlast(nd, MODEL_AXIS)),        # (d_inner, D)
)


def _last(nd, axis, over_first=False):
    spec = [None] * nd
    if over_first:
        spec[-2 if nd >= 2 else 0] = MODEL_AXIS   # embed (.., V, D) -> shard V
    else:
        spec[-1] = axis
    return P(*spec)


def _secondlast(nd, axis):
    spec = [None] * nd
    if nd >= 2:
        spec[-2] = axis
    else:
        spec[-1] = axis
    return P(*spec)


def _expert(nd):
    # routed expert weights are (n_groups?, E, D, F) — shard the expert dim.
    spec = [None] * nd
    spec[-3 if nd >= 3 else 0] = MODEL_AXIS
    return P(*spec)


def param_partition_spec(path: str, ndim: int) -> P:
    for key, builder in _RULES:
        if key in path:
            return builder(ndim)
    return P()  # norms, biases, scalars: replicated


def partition_pytree(params):
    """Map a parameter pytree to a pytree of PartitionSpecs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        specs.append(param_partition_spec(key, leaf.ndim))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, mesh: Optional[Mesh] = None):
    """NamedSharding pytree for a zoo parameter pytree on the client x
    model mesh: each leaf takes its ``_RULES`` MODEL_AXIS placement and
    is *replicated* over the client (pod, data) axes — every client
    trains the same parameters; only tensor parallelism splits them.
    Leaves whose named dim does not tile the model axis degrade to
    replicated (same per-dim contract as :func:`shard`).  None without
    a mesh."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return None
    specs = partition_pytree(params)

    def one(leaf, spec):
        s = _tiling_spec(leaf, spec, mesh) if spec else None
        return NamedSharding(mesh, s if s is not None else P())
    return jax.tree.map(one, params, specs)


def place_params(params, mesh: Optional[Mesh] = None):
    """Eagerly place a parameter pytree with :func:`param_shardings` —
    the one host->device scatter a model-sharded run performs, before
    the compiled segments take over.  No-op without a mesh or with a
    trivial model axis (replicated placement would change nothing the
    engine's constraints don't already pin)."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None or model_shard_count(mesh) <= 1:
        return params
    return jax.device_put(params, param_shardings(params, mesh))


def shard_params(params):
    """Traced twin of :func:`place_params`: per-leaf sharding
    constraints inside the compiled round body, so the updated
    parameters keep their tensor-parallel layout through the scan carry
    instead of drifting to whatever layout the unravel slice produces.
    No-op without a mesh or with a trivial model axis."""
    mesh = get_mesh()
    if mesh is None or model_shard_count(mesh) <= 1:
        return params
    specs = partition_pytree(params)

    def one(leaf, spec):
        s = _tiling_spec(leaf, spec, mesh) if spec else None
        if s is None:
            return leaf
        return jax.lax.with_sharding_constraint(leaf, NamedSharding(mesh, s))
    return jax.tree.map(one, params, specs)
