"""Compiled-HLO analysis: collective bytes, op census, roofline terms.

cost_analysis() gives HLO FLOPs and bytes accessed but NOT collective
traffic; we parse the compiled module text and sum the result sizes of
every collective op.  HLO text only annotates result types, so per-chip
moved bytes are estimated as result_bytes x factor (all-reduce counts
twice for its reduce+broadcast phases; ring (N-1)/N ~ 1 is folded in).
"""
from __future__ import annotations

import re
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# traffic factor per result byte (ring algorithms, large N)
FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
          "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def collective_stats(hlo_text: str) -> Dict[str, dict]:
    """Per collective kind: {count, result_bytes, moved_bytes}."""
    stats = {k: {"count": 0, "result_bytes": 0, "moved_bytes": 0.0}
             for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in COLLECTIVES:
            # match op invocations like "= bf16[..] all-reduce(" and
            # "= (f32[..], f32[..]) all-reduce-start(", not metadata
            if f" {kind}(" in line or f" {kind}-start(" in line:
                head = line.split(f" {kind}", 1)[0]
                if "=" not in head:
                    continue
                rhs = head.split("=", 1)[1]
                rbytes = sum(_shape_bytes(d, s)
                             for d, s in _SHAPE_RE.findall(rhs))
                stats[kind]["count"] += 1
                stats[kind]["result_bytes"] += rbytes
                stats[kind]["moved_bytes"] += rbytes * FACTOR[kind]
                break
    return stats


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["moved_bytes"] for v in collective_stats(hlo_text).values())


def op_census(hlo_text: str, ops=("fusion", "custom-call", "convolution",
                                  "dot", "scatter", "gather")) -> Dict[str, int]:
    census = {}
    for op in ops + COLLECTIVES:
        census[op] = len(re.findall(rf"\s{re.escape(op)}(?:-start)?\(", hlo_text))
    return census


# ----------------------------------------------------------------------
# v5e roofline constants
# ----------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link
ICI_LINKS = 3                   # effective links per chip (2D/3D torus)


def roofline_terms(cost: dict, collective_bytes: float) -> dict:
    """cost: compiled.cost_analysis() (per-device HLO module)."""
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_accessed / HBM_BW
    t_collective = collective_bytes / (ICI_BW_PER_LINK * ICI_LINKS)
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)), key=lambda kv: kv[1])[0]
    return {"flops": flops, "bytes": bytes_accessed,
            "collective_bytes": collective_bytes,
            "t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_collective, "dominant": dominant}
