"""Serving step: one decoded token against a seq-long cache (GSPMD jit).

Decode shapes lower this (not train_step): decode_32k = 128-way batched
decode with a 32k KV cache; long_500k = single-request 524k context for
the sub-quadratic archs (SSM state / SWA ring / seq-sharded hybrid KV).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import models
from ..sharding import use_mesh


def make_serve_step(cfg, mesh, donate_cache: bool = True):
    """serve_step(params, token, cache, cache_index)
       -> (next_token (B,1) int32, new_cache)."""

    def serve_fn(params, token, cache, cache_index):
        with use_mesh(mesh):
            logits, new_cache = models.decode_step(
                params, cfg, token, cache, cache_index)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, new_cache

    kwargs = {"donate_argnums": (2,)} if donate_cache else {}
    return jax.jit(serve_fn, **kwargs)


def make_prefill(cfg, mesh):
    """prefill(params, tokens, [enc_emb/cross_emb]) -> (last_logits, cache)
    (used by the serving example; dry-run prefill_32k lowers the forward)."""

    def prefill_fn(params, tokens, enc_emb=None, cross_emb=None):
        with use_mesh(mesh):
            out = models.apply(params, cfg, tokens, enc_emb=enc_emb,
                               cross_emb=cross_emb, want_cache=True)
            last = out["hidden"][:, -1:, :]
            logits = models.logits(params, cfg, last)
        return logits, out["cache"]

    return jax.jit(prefill_fn)


def make_forward(cfg, mesh):
    """Full-sequence forward + loss (what prefill_32k actually lowers for
    the roofline: the compute-shaped part of serving a 32k prompt)."""

    def fwd(params, tokens, enc_emb=None, cross_emb=None):
        with use_mesh(mesh):
            batch = {"tokens": tokens}
            if enc_emb is not None:
                batch["enc_emb"] = enc_emb
            if cross_emb is not None:
                batch["cross_emb"] = cross_emb
            return models.loss_fn(params, cfg, batch)

    return jax.jit(fwd)
