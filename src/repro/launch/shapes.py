"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Every model input is produced as a (spec, sharding) pair — weak-type
correct, shardable, no device allocation — following the
shannon/kernels dry-run pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from .mesh import client_axes, n_clients


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k only for sub-quadratic archs (SSM / hybrid / SWA);
    see DESIGN.md §4 for the documented skips."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def _batch_axes(mesh, batch: int):
    """Largest prefix of client axes that evenly divides the batch."""
    axes = []
    rem = batch
    for a in client_axes(mesh):
        sz = mesh.shape[a]
        if rem % sz == 0:
            axes.append(a)
            rem //= sz
        else:
            break
    return tuple(axes) if axes else None


def sds(shape, dtype, mesh=None, spec=None):
    s = jax.ShapeDtypeStruct(shape, dtype)
    if mesh is None:
        return s, None
    return s, NamedSharding(mesh, spec if spec is not None else P())


# ----------------------------------------------------------------------
# Train inputs (FL round step)
# ----------------------------------------------------------------------

def train_inputs(cfg: ModelConfig, shape: InputShape, mesh,
                 guide_batch: int = 1):
    """Returns ({name: ShapeDtypeStruct}, {name: NamedSharding}).

    - tokens       (B, S)              sharded over client axes
    - guide_tokens (n_clients, gb, S)  one enclave sample batch per client
    - byz_kind     (n_clients,) int32  per-client simulated fault
    - rng          (2,) uint32         round key (gaussian attack noise)
    - enc/cross embeddings where the arch needs them
    """
    nc = n_clients(mesh)
    caxes = client_axes(mesh)
    B, S = shape.batch, shape.seq
    specs, shardings = {}, {}

    def add(name, shp, dtype, spec):
        s, sh = sds(shp, dtype, mesh, spec)
        specs[name] = s
        shardings[name] = sh

    add("tokens", (B, S), jnp.int32, P(caxes, None))
    add("guide_tokens", (nc, guide_batch, S), jnp.int32, P(caxes, None, None))
    add("byz_kind", (nc,), jnp.int32, P(caxes))
    add("rng", (2,), jnp.uint32, P())
    if cfg.is_enc_dec:
        add("enc_emb", (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16,
            P(caxes, None, None))
        add("guide_enc_emb", (nc, guide_batch, cfg.enc_seq, cfg.d_model),
            jnp.bfloat16, P(caxes, None, None, None))
    elif cfg.has_cross:
        add("cross_emb", (B, cfg.n_patches, cfg.d_model), jnp.bfloat16,
            P(caxes, None, None))
        add("guide_cross_emb", (nc, guide_batch, cfg.n_patches, cfg.d_model),
            jnp.bfloat16, P(caxes, None, None, None))
    return specs, shardings


# ----------------------------------------------------------------------
# Serve inputs (single-token decode against a seq-long cache)
# ----------------------------------------------------------------------

def _cache_spec_tree(cfg: ModelConfig, cache, mesh, batch: int):
    """PartitionSpecs for a cache pytree: shard batch over client axes when
    divisible, otherwise shard the long (seq) dim of KV caches over the
    client axes (flash-decoding style); SSM states shard d_inner on model."""
    baxes = _batch_axes(mesh, batch)
    caxes = client_axes(mesh)

    def spec_for(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        nd = leaf.ndim
        if "conv" in key:                      # (G,B,dc,di)
            return P(*([None] * (nd - 1) + ["model"]))
        if "ssm" in key:                       # (G,B,di,S)
            return P(*([None] * (nd - 2) + ["model", None]))
        # kv caches: (G,B,C,K,dh) or (B,C,K,dh)
        bdim = nd - 4
        sdim = nd - 3
        spec = [None] * nd
        if baxes:
            spec[bdim] = baxes
        elif leaf.shape[sdim] >= 4096:
            spec[sdim] = caxes                 # seq-sharded long cache
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def serve_inputs(cfg: ModelConfig, shape: InputShape, mesh):
    """token (B,1), cache pytree (ShapeDtypeStructs), cache_index ()."""
    from ..models import model as _model
    B, S = shape.batch, shape.seq
    baxes = _batch_axes(mesh, B)
    cache = jax.eval_shape(lambda: _model.init_cache(cfg, B, S))
    cache_specs = _cache_spec_tree(cfg, cache, mesh, B)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs,
                            is_leaf=lambda x: isinstance(x, P))
    tok, tok_sh = sds((B, 1), jnp.int32, mesh, P(baxes, None))
    idx, idx_sh = sds((), jnp.int32, mesh, P())
    return ({"token": tok, "cache": cache, "cache_index": idx},
            {"token": tok_sh, "cache": cache_sh, "cache_index": idx_sh})
