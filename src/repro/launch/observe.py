"""Render a recorded run — span waterfall, round timeline, audit chain.

The flight recorder (fl/telemetry.py, DESIGN.md §11) exports one
training run as JSONL: a header, the span/event stream, and the
SecureServer's hash-chained audit log.  This CLI is the read side:

  * **verify** the audit chain end-to-end (every entry's digest
    recomputed against its predecessor — any mutation names the first
    bad entry and exits non-zero);
  * **waterfall** the spans (indented by nesting depth, with durations
    and the compile/sync events placed inside);
  * **timeline** the per-round telemetry (kept/tagged popcounts, C1/C2
    pass counts, update/guide norm summaries, uplink bytes) as one row
    per round — the paper's "the criterion tags exactly the faulty
    clients" claim, visible round by round.

Usage:
  PYTHONPATH=src python -m repro.launch.observe run.jsonl           # full
  PYTHONPATH=src python -m repro.launch.observe run.jsonl --summary # 1-line
  PYTHONPATH=src python -m repro.launch.observe --selftest          # CI job:
      record a small training in-process, export, verify, render
"""
from __future__ import annotations

import argparse
import sys

from ..fl import telemetry


# ----------------------------------------------------------------------
# rendering
# ----------------------------------------------------------------------

def _fmt_bytes(n) -> str:
    if n is None:
        return "-"
    n = float(n)
    for unit in ("B", "KB", "MB", "GB"):
        if n < 1024 or unit == "GB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.2f}{unit}"
        n /= 1024
    return f"{n:.2f}GB"


def render_waterfall(spans, events, out=sys.stdout):
    """Spans indented by depth, in start order; trace/sync events with a
    timestamp inside the window they fired in."""
    rows = []
    for s in spans:
        meta = {k: v for k, v in s.items()
                if k not in ("type", "name", "t0", "t1", "dur", "depth")
                and v is not None}
        rows.append((s["t0"], s.get("depth", 0), s["name"],
                     f"{s.get('dur', 0):8.3f}s",
                     " ".join(f"{k}={v}" for k, v in sorted(meta.items()))))
    for e in events:
        if e["kind"] in ("trace", "sync", "streaming_fallback",
                         "sweep_group_compiles"):
            meta = {k: v for k, v in e.items()
                    if k not in ("type", "kind", "t") and v is not None}
            if e["kind"] == "sync":
                meta["bytes"] = _fmt_bytes(meta.get("bytes"))
            rows.append((e["t"], 99, f"* {e['kind']}", f"@{e['t']:7.3f}s",
                        " ".join(f"{k}={v}" for k, v in sorted(meta.items()))))
    rows.sort(key=lambda r: r[0])
    print("-- span waterfall " + "-" * 44, file=out)
    for t, depth, name, dur, meta in rows:
        indent = "  " * min(depth, 6) if depth != 99 else "    "
        print(f"  {indent}{name:<28} {dur}  {meta}", file=out)


ROUND_COLS = ("kept", "tagged", "c1_pass", "c2_pass", "nonfinite", "cohort",
              "stale_buffered", "stale_folded", "stale_expired",
              "upd_norm_mean", "guide_norm_mean", "uplink_bytes")


def render_round_timeline(events, out=sys.stdout):
    """One row per recorded round: tag decisions, criterion pass counts,
    norm summaries, comm bytes."""
    rounds = [e for e in events if e["kind"] == "round"]
    if not rounds:
        print("-- no per-round telemetry recorded (FLConfig.telemetry "
              "was off) --", file=out)
        return
    cols = [c for c in ROUND_COLS if any(c in e for e in rounds)]
    has_cell = any("cell" in e for e in rounds)
    print("-- round timeline " + "-" * 44, file=out)
    hdr = "  round" + ("  cell" if has_cell else "")
    print(hdr + "".join(f"  {c:>15}" for c in cols), file=out)
    for e in rounds:
        row = f"  {e.get('index', '?'):>5}"
        if has_cell:
            row += f"  {e.get('cell', '-'):>4}"
        for c in cols:
            v = e.get(c)
            if v is None:
                cell = "-"
            elif c == "uplink_bytes":
                cell = _fmt_bytes(v)
            elif isinstance(v, float):
                cell = f"{v:.4f}"
            else:
                cell = str(v)
            row += f"  {cell:>15}"
        print(row, file=out)


def render_audit(audit, out=sys.stdout):
    verdict = telemetry.verify_entries(audit)
    print("-- enclave audit chain " + "-" * 39, file=out)
    kinds = {}
    for e in audit:
        kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
    print(f"  entries: {verdict.entries}  "
          + " ".join(f"{k}={v}" for k, v in sorted(kinds.items())), file=out)
    if verdict:
        head = audit[-1]["digest"][:16] if audit else telemetry.GENESIS[:16]
        print(f"  chain: VERIFIED (head {head}…)", file=out)
    else:
        print(f"  chain: BROKEN at entry {verdict.bad_index}: "
              f"{verdict.reason}", file=out)
    return bool(verdict)


def summarize(run) -> str:
    spans, events, audit = run["spans"], run["events"], run["audit"]
    syncs = [e for e in events if e["kind"] == "sync"]
    rounds = [e for e in events if e["kind"] == "round"]
    traces = [e for e in events if e["kind"] == "trace"]
    verdict = telemetry.verify_entries(audit)
    total = max((s.get("t1", 0) for s in spans), default=0.0)
    return (f"{len(spans)} spans over {total:.3f}s, {len(traces)} compiles, "
            f"{len(syncs)} syncs ({_fmt_bytes(sum(e.get('bytes', 0) for e in syncs))}), "
            f"{len(rounds)} round records, audit "
            f"{'VERIFIED' if verdict else 'BROKEN'} "
            f"({verdict.entries} entries)")


def render(path, summary_only=False, out=sys.stdout) -> bool:
    """Load + verify + render one exported run; True iff the audit chain
    verifies (the CLI's exit status)."""
    run = telemetry.load_jsonl(path)
    meta = run["header"].get("meta", {})
    if meta:
        print("meta: " + " ".join(f"{k}={v}"
                                  for k, v in sorted(meta.items())), file=out)
    if summary_only:
        print(summarize(run), file=out)
        return bool(telemetry.verify_entries(run["audit"]))
    render_waterfall(run["spans"], run["events"], out=out)
    render_round_timeline(run["events"], out=out)
    ok = render_audit(run["audit"], out=out)
    print(summarize(run), file=out)
    return ok


# ----------------------------------------------------------------------
# selftest — the CI observe-smoke job
# ----------------------------------------------------------------------

def selftest(path="/tmp/observe_selftest.jsonl") -> bool:
    """Record a small telemetry-enabled training end-to-end, export it,
    verify the audit chain (including tamper detection), and render both
    views.  Returns True on success — the observe-smoke CI job fails the
    build otherwise."""
    import jax
    import numpy as np

    from ..core.attacks import AttackConfig
    from ..data import (FederatedData, make_classification,
                        partition_sorted_shards)
    from ..fl import (FLConfig, Federation, run_federated_training,
                      softmax_regression)
    from ..optim import inv_sqrt_lr

    N, DIM, K = 16, 8, 3
    x, y = make_classification(jax.random.PRNGKey(0), N * 8, K, DIM)
    data = FederatedData.from_partitions(partition_sorted_shards(x, y, N), K)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, K, DIM)
    model = softmax_regression(input_dim=DIM, n_classes=K)

    def train(tel):
        cfg = FLConfig(n_clients=N, f=3, rounds=7, eval_every=3,
                       batch_size=2, attack=AttackConfig(kind="sign_flip"),
                       telemetry=tel)
        fed = Federation.create(model, data, tx, ty, cfg,
                                jax.random.PRNGKey(2))
        return run_federated_training(model, fed, cfg,
                                      inv_sqrt_lr(0.05)), fed

    h_off, _ = train(False)
    with telemetry.recording() as rec:
        h_on, fed = train(True)
        telemetry.export_jsonl(path, recorder=rec, audit=fed.server.audit,
                               meta={"run": "observe-selftest"})

    # telemetry must not perturb the training: histories bitwise-equal
    for k in ("round", "acc", "mask_tpr", "mask_fpr"):
        assert np.array_equal(np.asarray(h_off[k]), np.asarray(h_on[k])), \
            f"telemetry changed history[{k!r}]"

    run = telemetry.load_jsonl(path)
    assert len([e for e in run["events"] if e["kind"] == "sync"]) == 1, \
        "one-dispatch run must record exactly one sync event"
    assert len([e for e in run["events"] if e["kind"] == "round"]) == 7, \
        "expected one round record per round"
    assert telemetry.verify_entries(run["audit"]), "audit chain broken"

    # the chain must actually bind: a mutated entry fails verification
    import copy
    tampered = copy.deepcopy(run["audit"])
    tampered[len(tampered) // 2]["data"]["forged"] = 1
    bad = telemetry.verify_entries(tampered)
    assert not bad and bad.bad_index == len(tampered) // 2, \
        "tampered audit entry went undetected"

    ok = render(path)

    # async leg (DESIGN.md §13): a faulty, cohort-resampled, buffered run
    # must land cohort_resample + stale_* entries on the audit chain and
    # the new timeline columns in the round records — and still verify
    from ..fl.faults import FaultConfig
    async_path = path.replace(".jsonl", "_async.jsonl")
    cfg = FLConfig(n_clients=N, f=3, rounds=7, eval_every=3, batch_size=2,
                   attack=AttackConfig(kind="sign_flip"), streaming=True,
                   telemetry=True, cohort_participation=0.75,
                   staleness_buffer=4,
                   fault=FaultConfig(kind="straggler", rate=0.3, delay=1))
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    with telemetry.recording() as rec:
        run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))
        telemetry.export_jsonl(async_path, recorder=rec,
                               audit=fed.server.audit,
                               meta={"run": "observe-selftest-async"})
    arun = telemetry.load_jsonl(async_path)
    kinds = {e["kind"] for e in arun["audit"]}
    assert "cohort_resample" in kinds, \
        "async run recorded no cohort_resample audit entries"
    assert kinds & {"stale_buffered", "stale_folded", "stale_expired"}, \
        "async straggler run recorded no stale_* audit entries"
    rounds = [e for e in arun["events"] if e["kind"] == "round"]
    assert rounds and all("cohort" in e and "stale_buffered" in e
                          for e in rounds), \
        "async round telemetry missing cohort/stale columns"
    assert telemetry.verify_entries(arun["audit"]), \
        "async audit chain broken"
    ok = render(async_path) and ok

    print("observe selftest: OK")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", nargs="?", help="exported run (JSONL)")
    ap.add_argument("--summary", action="store_true",
                    help="one-line summary instead of the full render")
    ap.add_argument("--selftest", action="store_true",
                    help="record + export + verify + render a tiny run")
    args = ap.parse_args(argv)
    if args.selftest:
        return 0 if selftest() else 1
    if not args.path:
        ap.error("need a JSONL path (or --selftest)")
    return 0 if render(args.path, summary_only=args.summary) else 1


if __name__ == "__main__":
    raise SystemExit(main())
