"""The FL round step at pod scale — DiverseFL Steps 2–5 as ONE SPMD program.

Mesh axes ("pod","data","model"): each FL client is one (pod,data)
coordinate and owns a model-parallel slice group of 16 chips.  The round
step runs inside ``jax.shard_map`` *manual* over the client axes and
*auto* over ``model`` — tensor/expert parallelism needs no hand-written
collectives, while the FL semantics are explicit:

  1. client local SGD (E steps) on the local batch  -> update z_j
  2. (test-only) simulated Byzantine corruption of z_j
  3. guiding update Δ̃_j on the client's enclave sample (same E, same lr)
  4. per-client similarity scalars via shard-local reductions
     (GSPMD inserts the psum over ``model``)                 [C1/C2]
  5. masked mean over the client axes: one psum               [Eq. 6]

Per-client updates are never materialized N-fold: each client's update
lives only on its own mesh slice, and the criterion needs 3 scalars.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import models
from ..core.diversefl import (DiverseFLConfig, criterion_logs, diversefl_mask,
                              similarity_stats_tree)
from ..sharding import partition_pytree, use_mesh
from .mesh import client_axes, n_clients

F32 = jnp.float32

# simulated fault codes (cheap, RNG-free: part of the compiled step only
# for integration testing; 0 in production)
FAULT_NONE, FAULT_SIGN_FLIP, FAULT_SAME_VALUE, FAULT_SCALE = 0, 1, 2, 3
SAME_VALUE_SIGMA = 100.0
SCALE_FACTOR = 5.0


def update_psum_dtype(update_dtype):
    """The dtype client updates are all-reduced in.

    XLA:CPU's AllReducePromotion pass CHECK-fails cloning a bf16
    all-reduce (host dry-run only); TPU does bf16 all-reduce natively,
    so the cast up to f32 is gated on the backend.  One definition so
    the workaround is pinned by a regression test
    (tests/test_compression.py) instead of living as an inline branch a
    refactor can silently drop."""
    return F32 if jax.default_backend() == "cpu" else update_dtype


def resolve_update_dtype(compression: str, update_dtype=None):
    """Map a codec name to the wire dtype the shard_map round step
    carries client updates in.

    The pod-scale step moves updates as *native arrays* through psums —
    a dense-payload codec (``fl/compression.Codec.wire_dtype`` set:
    f32, bf16) IS a dtype choice there, so both launch knobs route
    through the one codec registry the simulator uses.  Codecs that
    need a scale sidecar (int8) have no single wire dtype and raise a
    named error rather than silently degrading.  ``update_dtype`` is
    the legacy knob: when given it must agree with ``compression``
    (or ``compression`` must be the default)."""
    from ..fl.compression import get_codec
    codec = get_codec(compression)
    if codec.wire_dtype is None:
        raise ValueError(
            f"compression={compression!r} has no dense wire dtype: the "
            f"pod-scale shard_map round step psums native update arrays "
            f"and cannot carry the {compression!r} scale sidecar — use "
            f"'f32'/'bf16' here, or run the int8 path through the "
            f"simulator's streaming fold (fl/engine.py)")
    if update_dtype is not None and update_dtype != codec.wire_dtype \
            and compression != "f32":
        raise ValueError(
            f"update_dtype={jnp.dtype(update_dtype).name!r} conflicts "
            f"with compression={compression!r} "
            f"(wire dtype {jnp.dtype(codec.wire_dtype).name!r})")
    return update_dtype if update_dtype is not None else codec.wire_dtype


def _local_batch(cfg, inputs):
    b = {"tokens": inputs["tokens"]}
    if "enc_emb" in inputs:
        b["enc_emb"] = inputs["enc_emb"]
    if "cross_emb" in inputs:
        b["cross_emb"] = inputs["cross_emb"]
    return b


def _guide_batch(cfg, inputs):
    b = {"tokens": inputs["guide_tokens"][0]}
    if "guide_enc_emb" in inputs:
        b["enc_emb"] = inputs["guide_enc_emb"][0]
    if "guide_cross_emb" in inputs:
        b["cross_emb"] = inputs["guide_cross_emb"][0]
    return b


def make_fl_round_step(cfg, mesh, dfl: DiverseFLConfig = DiverseFLConfig(),
                       lr: float = 1e-3, local_steps: int = 1,
                       donate: bool = True, update_dtype=None,
                       robust_mode: str = "diversefl",
                       compression: str = "f32"):
    """Returns a jit'd round_step(params, inputs) -> (new_params, metrics).

    ``inputs`` is the dict produced by launch.shapes.train_inputs.
    ``compression``: codec name from the fl/compression registry naming
    the dtype client updates are carried/psum'd in ("f32"/"bf16" — see
    :func:`resolve_update_dtype`).  f32 is the paper-faithful baseline;
    bf16 is the beyond-paper variant (halves update HBM traffic and
    aggregation collective volume; the C1/C2 similarity stats are still
    accumulated in fp32 — see EXPERIMENTS.md §Perf).  ``update_dtype``
    is the legacy spelling of the same knob (kept so existing callers
    and benches run unchanged); it must agree with ``compression`` when
    both are given.

    ``robust_mode``: "diversefl" (per-client criteria + masked mean — the
    paper) or "median" (coordinate-wise median across clients — the
    cross-client baseline family).  Median requires every chip to hold
    all N client update shards simultaneously (an all-gather over the
    client axes); it exists here to *quantify* the systems gap between
    cross-client statistics and DiverseFL's 3-scalars-per-client at pod
    scale (EXPERIMENTS.md §Perf, "median at scale").
    """
    assert robust_mode in ("diversefl", "median")
    caxes = client_axes(mesh)
    nc = n_clients(mesh)
    UDT = resolve_update_dtype(compression, update_dtype)

    def local_loss(params, batch):
        return models.loss_fn(params, cfg, batch)

    def client_update(params, batch):
        """Δ = θ0 - θE after E local SGD steps (E=1: just lr * grad)."""
        if local_steps == 1:
            loss, g = jax.value_and_grad(local_loss)(params, batch)
            return jax.tree.map(lambda x: (lr * x.astype(F32)).astype(UDT),
                                g), loss

        def step(theta, _):
            g = jax.grad(local_loss)(theta, batch)
            theta = jax.tree.map(
                lambda t, gg: (t.astype(F32) - lr * gg.astype(F32)).astype(t.dtype),
                theta, g)
            return theta, None
        theta, _ = jax.lax.scan(step, params, None, length=local_steps)
        delta = jax.tree.map(
            lambda a, b: (a.astype(F32) - b.astype(F32)).astype(UDT),
            params, theta)
        return delta, local_loss(params, batch)

    def round_fn(params, inputs):
        # ---- Step 2: client local training on the local shard ----
        z, loss = client_update(params, _local_batch(cfg, inputs))

        # ---- simulated Byzantine faults (integration testing) ----
        kind = inputs["byz_kind"][0]
        mult = jnp.where(kind == FAULT_SIGN_FLIP, -1.0, 1.0) * \
            jnp.where(kind == FAULT_SCALE, SCALE_FACTOR, 1.0)
        z = jax.tree.map(
            lambda u: jnp.where(kind == FAULT_SAME_VALUE,
                                jnp.asarray(SAME_VALUE_SIGMA, u.dtype),
                                u * mult.astype(u.dtype)), z)

        if robust_mode == "median":
            # cross-client baseline: gather all client updates, take the
            # coordinate-wise median.  N x update memory + collective —
            # deliberately so (see docstring).
            def med(u):
                allu = jax.lax.all_gather(u, caxes)
                allu = allu.reshape((-1,) + u.shape)
                return jnp.median(allu, axis=0)
            agg = jax.tree.map(med, z)
            new_params = jax.tree.map(
                lambda p, a: (p.astype(F32) - a.astype(F32)).astype(p.dtype),
                params, agg)
            metrics = {"loss": jax.lax.pmean(loss, caxes),
                       "kept": jnp.float32(nc),
                       "mask": jnp.ones((1,), bool),
                       "c1": jnp.ones((1,)), "c2": jnp.ones((1,))}
            return new_params, metrics

        # ---- Step 3: guiding update on the enclave sample ----
        g, _ = client_update(params, _guide_batch(cfg, inputs))

        # ---- Step 4: per-client similarity scalars (psum over model is
        #      inserted by GSPMD; client axes are manual => per-client).
        #      similarity_stats_tree reduces per-leaf elementwise products
        #      (never jnp.vdot), keeping partial sums shard-local — see
        #      core/diversefl.py (§Perf A2). ----
        dot, zz, gg = similarity_stats_tree(z, g)
        mask = diversefl_mask(dot, zz, gg, dfl)

        # ---- Step 5: masked mean over clients (Eq. 6) + model update ----
        m = mask.astype(F32)
        cnt = jax.lax.psum(m, caxes)
        denom = jnp.maximum(cnt, 1.0)
        psum_dt = update_psum_dtype(UDT)
        agg = jax.tree.map(
            lambda u: jax.lax.psum((u * m.astype(u.dtype)).astype(psum_dt),
                                   caxes).astype(F32) / denom, z)
        new_params = jax.tree.map(
            lambda p, a: (p.astype(F32) - a).astype(p.dtype), params, agg)

        crit = criterion_logs(dot, zz, gg)
        metrics = {
            "loss": jax.lax.pmean(loss, caxes),
            "kept": cnt,
            "mask": mask.reshape(1),
            "c1": crit["c1"].reshape(1),
            "c2": crit["c2"].reshape(1),
        }
        return new_params, metrics

    # in/out specs: params replicated over client axes (model handled auto);
    # batch-like inputs split over client axes on dim 0.
    def in_spec_for(name, ndim):
        if name == "rng":
            return P()
        return P(*((caxes,) + (None,) * (ndim - 1)))

    def round_step_fn(params, inputs):
        input_specs = {k: in_spec_for(k, inputs[k].ndim) for k in inputs}
        params_specs = jax.tree.map(lambda _: P(), params)
        out_metric_specs = {"loss": P(), "kept": P(), "mask": P(caxes),
                            "c1": P(caxes), "c2": P(caxes)}
        f = jax.shard_map(
            round_fn, mesh=mesh,
            in_specs=(params_specs, input_specs),
            out_specs=(params_specs, out_metric_specs),
            axis_names=set(caxes), check_vma=False)
        with use_mesh(mesh):
            return f(params, inputs)

    jit_kwargs = {}
    if donate:
        jit_kwargs["donate_argnums"] = (0,)
    return jax.jit(round_step_fn, **jit_kwargs)


def sharded_param_specs(cfg, mesh):
    """ShapeDtypeStructs (with NamedShardings) for the model params."""
    shapes = jax.eval_shape(
        functools.partial(models.init, jax.random.PRNGKey(0), cfg))
    specs = partition_pytree(shapes)
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        shapes, specs)


# ----------------------------------------------------------------------
# Launcher CLI: federated training of a real zoo arch on the host
# client x model mesh, driven by the compiled round engine — the SAME
# Steps 2-5 definition (fl/engine.make_round_body) every simulator run
# and benchmark uses, with the flat D model-sharded over ``model``
# (DESIGN.md §12).  ``make_fl_round_step`` above stays as the explicit
# shard_map lowering reference (dryrun.py compiles it against the
# production mesh; tests/test_sharded_step.py pins its semantics) but
# no driver loops over it anymore: the engine path IS the launcher.
#
#   PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --rounds 10
# ----------------------------------------------------------------------

def main(argv=None):
    import argparse

    import numpy as np
    from ..core.attacks import AttackConfig
    from ..fl.engine import RoundEngine
    from ..fl.simulator import FLConfig
    from ..fl.zoo import make_zoo_federation, zoo_model
    from .mesh import make_host_mesh, n_clients as _nc

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--rounds", "--steps", dest="rounds", type=int,
                    default=10)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--byzantine", type=int, default=1,
                    help="number of sign-flipping clients")
    ap.add_argument("--eval-every", type=int, default=5)
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    mesh = make_host_mesh(data=max(1, n_dev // 2), model=2 if n_dev > 1 else 1)
    nc = _nc(mesh)
    model = zoo_model(args.arch, seq_len=args.seq, smoke=True)
    print(f"launch: {model.name} ({model.param_count():,} params) on mesh "
          f"{dict(mesh.shape)} ({nc} clients)")

    cfg = FLConfig(
        n_clients=nc, f=args.byzantine, rounds=args.rounds,
        batch_size=args.batch, l2=0.0, aggregator="diversefl",
        streaming=True, eval_every=min(args.eval_every, args.rounds),
        attack=AttackConfig(kind="sign_flip" if args.byzantine else "none"))
    fed = make_zoo_federation(model, cfg, per_client=max(args.batch, 8))

    engine = RoundEngine(model, fed, cfg, mesh=mesh)
    params, _, metrics, eval_rounds = engine.run_training(
        model.init(jax.random.PRNGKey(cfg.seed + 1)),
        jax.random.PRNGKey(cfg.seed),
        jnp.full((cfg.rounds,), args.lr, jnp.float32))
    for r, acc in zip(np.asarray(eval_rounds), np.asarray(metrics["acc"])):
        print(f"  round {int(r):3d} acc={float(acc):.4f}")
    del params


if __name__ == "__main__":
    main()
