"""Production mesh construction.

Single pod: 16x16 = 256 v5e chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model").

FL clients live on the (pod, data) axes — 16 clients/pod — and tensor/
expert parallelism on "model".  A function (not a module constant) so
importing never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def client_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def n_clients(mesh) -> int:
    out = 1
    for a in client_axes(mesh):
        out *= mesh.shape[a]
    return out
