"""Production mesh construction.

Single pod: 16x16 = 256 v5e chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model").

FL clients live on the (pod, data) axes — 16 clients/pod — and tensor/
expert parallelism on "model".  A function (not a module constant) so
importing never touches jax device state.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """One mesh constructor for every helper here: newer JAX wants
    explicit Auto axis types; older JAX builds the device array
    directly.  Same mesh either way."""
    if hasattr(jax.sharding, "AxisType"):   # newer JAX
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    import math
    import numpy as np
    need = math.prod(shape)
    return jax.sharding.Mesh(
        np.array(jax.devices()[:need]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return _mesh((data, model), ("data", "model"))


def make_host_pod_mesh(pods: int = 2, data: int = 1, model: int = 1):
    """Multi-pod mesh over the locally available devices, axes
    ``("pod", "data", "model")`` — the test/bench twin of the multi-pod
    production mesh, for exercising the hierarchical two-tier
    aggregation (fl/streaming.py, DESIGN.md §9) without pod hardware.

    Fails with a named error instead of an opaque device-count assert;
    host runs force the device count via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    jax initializes — tests do this in subprocesses)."""
    n = len(jax.devices())
    need = pods * data * model
    if n < need:
        raise ValueError(
            f"host pod mesh ({pods} pods x {data} data x {model} model) "
            f"needs {need} devices but only {n} are available; force host "
            f"devices with XLA_FLAGS=--xla_force_host_platform_device_count"
            f"={need} before jax initializes")
    axes = ("pod", "data", "model")
    if hasattr(jax.sharding, "AxisType"):   # newer JAX
        return jax.make_mesh((pods, data, model), axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    import numpy as np
    return jax.sharding.Mesh(
        np.array(jax.devices()[:need]).reshape(pods, data, model), axes)


def client_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def n_clients(mesh) -> int:
    out = 1
    for a in client_axes(mesh):
        out *= mesh.shape[a]
    return out
