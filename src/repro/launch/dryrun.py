import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")   # silence SPMD warnings

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers and compiles for the production meshes, and extract
the roofline inputs (memory_analysis, cost_analysis, collective schedule)
from the compiled artifact.  No real allocation: every input is a
ShapeDtypeStruct.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all combos
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
      --shape train_4k --mesh both
  ... --out results/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from .. import configs
from ..core.diversefl import DiverseFLConfig
from . import hlo as hlo_lib
from .mesh import make_production_mesh
from .serve import make_prefill, make_serve_step
from .shapes import SHAPES, applicable, serve_inputs, train_inputs
from .train import make_fl_round_step, sharded_param_specs


def _cost_dict(compiled):
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c) if c else {}


def _mem_dict(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def dryrun_one(arch_id: str, shape_name: str, multi_pod: bool,
               verbose: bool = True, opt: bool = False) -> dict:
    t0 = time.time()
    cfg = configs.get(arch_id)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "status": "ok", "opt": opt}
    if not applicable(cfg, shape):
        rec["status"] = "skip"
        rec["reason"] = ("full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md §4)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    params = sharded_param_specs(cfg, mesh)

    if shape.kind == "train":
        specs, _ = train_inputs(cfg, shape, mesh)
        step = make_fl_round_step(
            cfg, mesh, DiverseFLConfig(), donate=False,
            compression="bf16" if opt else "f32")
        lowered = step.lower(params, specs)
    elif shape.kind == "prefill":
        prefill = make_prefill(cfg, mesh)
        from .shapes import sds
        from ..launch.mesh import client_axes
        from jax.sharding import PartitionSpec as P
        caxes = client_axes(mesh)
        tok, _ = sds((shape.batch, shape.seq), jnp.int32, mesh,
                     P(caxes, None))
        tok = jax.ShapeDtypeStruct(tok.shape, tok.dtype,
                                   sharding=_nsh(mesh, P(caxes, None)))
        kwargs = {}
        if cfg.is_enc_dec:
            kwargs["enc_emb"] = jax.ShapeDtypeStruct(
                (shape.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16,
                sharding=_nsh(mesh, P(caxes, None, None)))
        elif cfg.has_cross:
            kwargs["cross_emb"] = jax.ShapeDtypeStruct(
                (shape.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16,
                sharding=_nsh(mesh, P(caxes, None, None)))
        lowered = prefill.lower(params, tok, **kwargs)
    else:  # decode
        specs, shardings = serve_inputs(cfg, shape, mesh)
        specs = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
            if sh is not None else s, specs, shardings,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        step = make_serve_step(cfg, mesh, donate_cache=False)
        lowered = step.lower(params, specs["token"], specs["cache"],
                             specs["cache_index"])

    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    cost = _cost_dict(compiled)
    mem = _mem_dict(compiled)
    text = compiled.as_text()
    coll = hlo_lib.collective_stats(text)
    rec["cost"] = {k: float(v) for k, v in cost.items()
                   if isinstance(v, (int, float))}
    rec["memory"] = mem
    rec["collectives"] = coll
    rec["collective_bytes"] = hlo_lib.total_collective_bytes(text)
    rec["roofline"] = hlo_lib.roofline_terms(cost, rec["collective_bytes"])
    if verbose:
        r = rec["roofline"]
        print(f"[{rec['status']:4s}] {arch_id:22s} {shape_name:12s} "
              f"{mesh_name:8s} lower={rec['lower_s']:7.1f}s "
              f"compile={rec['compile_s']:7.1f}s "
              f"flops={r['flops']:.3e} bytes={r['bytes']:.3e} "
              f"coll={r['collective_bytes']:.3e} dom={r['dominant']}")
        print(f"       memory_analysis: {mem}")
    return rec


def _nsh(mesh, spec):
    from jax.sharding import NamedSharding
    return NamedSharding(mesh, spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--bf16", "--opt", dest="opt", action="store_true",
                    help="beyond-paper optimized round step: bf16 update "
                         "codec (fl/compression.py; --opt is the legacy "
                         "spelling)")
    args = ap.parse_args()

    archs = configs.all_arch_ids() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    records.append(dryrun_one(arch, shape, mp, opt=args.opt))
                except Exception as e:
                    traceback.print_exc()
                    records.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "status": "error", "error": repr(e)})
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} documented skips, {n_err} errors")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print("wrote", args.out)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
