"""Byzantine-robust aggregation baselines the paper compares against
(Sec. IV + Appendix A).  All operate on a stacked update matrix
``U: (N, D)`` (clients × flattened model dim), fp32.

  - oracle_sgd : mean over the (oracle-known) benign set
  - median     : coordinate-wise median [Yin et al., 9]
  - trimmed_mean: coordinate-wise trimmed mean (beta / closest-to-median)
  - krum       : update of the client closest to its N-f-2 neighbours [8]
  - bulyan     : recursive Krum selection + per-dim trimmed mean [12]
  - resampling : s_R-fold resample-and-average then Median [24]
  - fltrust    : root-update projection + ReLU cosine weighting [26]

RSA [23] maintains per-client model copies and is a *training rule*, not
a one-shot aggregator — it lives in fl/rsa.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .diversefl import masked_mean_flat


def flatten_updates(updates):
    """pytree with leading client dim N -> (N, D) fp32 matrix + unravel fn."""
    leaves = jax.tree.leaves(updates)
    n = leaves[0].shape[0]
    flat = jnp.concatenate(
        [u.reshape(n, -1).astype(jnp.float32) for u in leaves], axis=1)

    treedef = jax.tree.structure(updates)
    shapes = [u.shape[1:] for u in leaves]
    sizes = [int(math.prod(s)) for s in shapes]

    def unravel(vec):
        outs, off = [], 0
        for s, sz in zip(shapes, sizes):
            outs.append(vec[off:off + sz].reshape(s))
            off += sz
        return jax.tree.unflatten(treedef, outs)
    return flat, unravel


# ----------------------------------------------------------------------

def oracle_sgd(U, benign_mask):
    return masked_mean_flat(U, benign_mask)


def median(U):
    return jnp.median(U, axis=0)


def trimmed_mean(U, f: int, mode: str = "beta"):
    """mode='beta': drop largest/smallest f per dim [9].
    mode='near_median': keep N-2f values closest to the median per dim [12]."""
    N = U.shape[0]
    if mode == "beta":
        s = jnp.sort(U, axis=0)
        kept = s[f:N - f] if N - 2 * f > 0 else s
        return kept.mean(0)
    med = jnp.median(U, axis=0)
    d = jnp.abs(U - med[None, :])
    keep_n = max(N - 2 * f, 1)
    idx = jnp.argsort(d, axis=0)[:keep_n]                    # (keep_n, D)
    vals = jnp.take_along_axis(U, idx, axis=0)
    return vals.mean(0)


def _pairwise_sq_dists(U):
    sq = jnp.sum(U * U, axis=1)
    return sq[:, None] + sq[None, :] - 2.0 * (U @ U.T)


def krum_scores(U, f: int, active=None):
    """Sum of distances to the nearest N-f-2 other clients (lower = better).

    ``active``: optional bool mask of clients still in play (Bulyan)."""
    N = U.shape[0]
    d = _pairwise_sq_dists(U)
    big = jnp.float32(1e30)
    d = d + jnp.eye(N, dtype=U.dtype) * big                  # exclude self
    if active is not None:
        inact = ~active
        d = jnp.where(inact[None, :], big, d)
        n_active = active.sum()
    else:
        n_active = N
    k = jnp.clip(n_active - f - 2, 1, N - 1)
    s = jnp.sort(d, axis=1)
    ar = jnp.arange(N - 0)
    # sum of the k smallest distances per row (k is dynamic under masking)
    cums = jnp.cumsum(s, axis=1)
    scores = jnp.take_along_axis(
        cums, jnp.broadcast_to(k - 1, (N, 1)).astype(jnp.int32), axis=1)[:, 0]
    if active is not None:
        scores = jnp.where(active, scores, big)
    return scores


def krum(U, f: int):
    return U[jnp.argmin(krum_scores(U, f))]


def bulyan(U, f: int):
    """Recursive Krum to select N-2f candidates, then the [12] trimmed mean
    (per dim: mean of the N'-2f values closest to the median)."""
    N = U.shape[0]
    n_sel = max(N - 2 * f, 1)

    def pick(carry, _):
        active = carry
        scores = krum_scores(U, f, active)
        j = jnp.argmin(scores)
        return active.at[j].set(False), j

    active0 = jnp.ones((N,), bool)
    _, sel = jax.lax.scan(pick, active0, None, length=n_sel)
    V = U[sel]                                               # (n_sel, D)
    f2 = max(min(f, (n_sel - 1) // 2), 0)
    if n_sel - 2 * f2 <= 0:
        f2 = max((n_sel - 1) // 2, 0)
    return trimmed_mean(V, f2, mode="near_median")


def resampling(U, key, s_r: int = 2, robust=median):
    """[24]: build N averaged groups with each client used <= s_r times."""
    N = U.shape[0]
    # sample without exceeding s_r uses: shuffle s_r copies of client ids
    ids = jnp.tile(jnp.arange(N), s_r)
    ids = jax.random.permutation(key, ids)[: N * s_r].reshape(N, s_r)
    V = U[ids].mean(axis=1)                                  # (N, D)
    return robust(V)


def fltrust(U, root_update):
    """[26]: TS_j = ReLU(cos(root, z_j)); rescale z_j to ‖root‖; weighted avg.

    Written layout-stably, so the same bits come out whether the rule
    runs solo or as one cell of a vmapped sweep (fl/sweep.py's bitwise
    contract): per-client statistics are multiply + last-axis
    reductions (never a matvec, whose contraction order shifts under
    batching), and both client-axis reductions — the weighted sum AND
    the trust-score denominator — go through one canonical left fold in
    client order, exactly the ``(s + u·a_i, n + ts_i)`` association the
    streaming fltrust rule folds (fl/streaming.weighted_mean_rule).
    Unlike ``masked_sum_fold`` this fold runs **unrolled=1**: fltrust's
    weights are real-valued, and an unrolled fold body gives XLA:CPU a
    multiply-add chain it may emit as FMA — solo and vmapped lowerings
    choose differently, so the same fold produces different bits across
    layouts whenever the products ``u·a_i`` round (the 0/1 mask weights
    of the other rules have exact products, which is why their unrolled
    fold is immune).  One iteration per client keeps the body a single
    mul + add that lowers identically everywhere — determinism over
    speed, the same trade ``masked_sum_fold`` documents."""
    r = root_update.astype(jnp.float32)
    rn = jnp.sqrt(jnp.sum(r * r)) + 1e-12
    Uf = U.astype(jnp.float32)
    un = jnp.sqrt(jnp.sum(Uf * Uf, axis=-1)) + 1e-12
    ts = jax.nn.relu(jnp.sum(Uf * r, axis=-1) / (un * rn))
    a = ts * (rn / un)

    def step(carry, xs):
        u, ai, ti = xs
        s, n = carry
        return (s + u * ai, n + ti), None

    init = (jnp.zeros(Uf.shape[1:], jnp.float32), jnp.float32(0.0))
    (s, n), _ = jax.lax.scan(step, init, (Uf, a, ts))
    return s / jnp.maximum(n, 1e-12)
