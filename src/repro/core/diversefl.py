"""DiverseFL — the paper's contribution (Sec. III).

Per-client Byzantine mitigation: the server (inside the TEE enclave)
computes, for every participating client j, a *guiding update* Δ̃_j by
running the same E local-SGD steps on the small sample M_j^0 the client
shared once before training.  The client's uploaded update z_j is kept
iff both similarity conditions hold:

    C1 = sign(Δ̃_j · z_j)            C1 > ε1            (direction, Eq. 2/4)
    C2 = ‖z_j‖₂ / ‖Δ̃_j‖₂            ε2 < C2 < ε3        (length,   Eq. 3/5)

and the global model is updated with the plain mean of surviving updates
(Eq. 6).  Paper defaults: (ε1, ε2, ε3) = (0, 0.5, 2).

Two implementations co-exist:
  * pytree-level (this module) — used by the FL simulator and at paper
    scale; stats are exact fp32 reductions over the update pytrees.
  * kernels/similarity.py — fused one-HBM-pass Pallas kernel over
    flattened updates, used on TPU at framework scale.

At pod scale the same criterion runs inside the sharded FL round step
(launch/train.py): each client's (dot, ‖z‖², ‖Δ̃‖²) is reduced
shard-locally and psum'd over the ``model`` axis, so per-client updates
are never materialized N-fold.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DiverseFLConfig:
    eps1: float = 0.0     # direction threshold: require dot > eps1 (sign test)
    eps2: float = 0.5     # length ratio lower bound
    eps3: float = 2.0     # length ratio upper bound
    local_steps: int = 1  # E
    sample_frac: float = 0.01


# ----------------------------------------------------------------------
# Similarity statistics
# ----------------------------------------------------------------------

def similarity_stats(z: jnp.ndarray, g: jnp.ndarray):
    """Flat-vector stats: (z·g, ‖z‖², ‖g‖²) in fp32."""
    z = z.astype(jnp.float32)
    g = g.astype(jnp.float32)
    return jnp.vdot(z, g), jnp.vdot(z, z), jnp.vdot(g, g)


def similarity_stats_tree(z_tree, g_tree):
    """Pytree stats: sums reductions across leaves (exact, fp32)."""
    dots = jax.tree.map(
        lambda z, g: jnp.vdot(z.astype(jnp.float32), g.astype(jnp.float32)),
        z_tree, g_tree)
    zz = jax.tree.map(lambda z: jnp.vdot(z.astype(jnp.float32),
                                         z.astype(jnp.float32)), z_tree)
    gg = jax.tree.map(lambda g: jnp.vdot(g.astype(jnp.float32),
                                         g.astype(jnp.float32)), g_tree)
    s = lambda t: jnp.sum(jnp.stack(jax.tree.leaves(t)))
    return s(dots), s(zz), s(gg)


def diversefl_mask(dot, z_sq, g_sq, cfg: DiverseFLConfig):
    """Boolean keep-mask from per-client stats (any shape, elementwise).

    Condition 1: C1 = sign(Δ̃·z): kept iff dot > eps1 (eps1=0 reproduces the
    paper's sign test).  Condition 2: eps2 < ‖z‖/‖Δ̃‖ < eps3, evaluated in
    squared form to avoid sqrt of tiny values.
    """
    c1 = dot > cfg.eps1
    ratio_sq = z_sq / jnp.maximum(g_sq, 1e-30)
    c2 = (ratio_sq > cfg.eps2 ** 2) & (ratio_sq < cfg.eps3 ** 2)
    return c1 & c2


# ----------------------------------------------------------------------
# Guiding update (enclave Step 3)
# ----------------------------------------------------------------------

def guiding_update(params, guide_batch, grad_fn: Callable, lr, E: int = 1):
    """Δ̃ = θ - SGD_E(θ; M^0): E gradient-descent steps on the enclave sample.

    grad_fn(params, batch) -> grad pytree.  Mirrors the client's local
    optimizer exactly (plain SGD, same lr, same E) per Algorithm 1.
    """
    theta = params

    def step(theta, _):
        g = grad_fn(theta, guide_batch)
        theta = jax.tree.map(lambda t, gg: t - lr * gg.astype(t.dtype), theta, g)
        return theta, None

    theta, _ = jax.lax.scan(step, theta, None, length=E)
    return jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), params, theta)


# ----------------------------------------------------------------------
# Aggregation (Eq. 6)
# ----------------------------------------------------------------------

def masked_mean(updates, mask):
    """updates: pytree with leading client dim N; mask: (N,) bool/float."""
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(m.sum(), 1.0)

    def agg(u):
        mm = m.reshape((-1,) + (1,) * (u.ndim - 1))
        return (u.astype(jnp.float32) * mm).sum(0) / denom
    return jax.tree.map(agg, updates)


def diversefl_aggregate(updates, guides, cfg: DiverseFLConfig):
    """Full Step 4+5 at simulator scale.

    updates/guides: pytrees whose leaves have leading client dim N.
    Returns (aggregated update pytree, keep mask (N,), stats dict)."""
    def stats_one(z, g):
        return similarity_stats_tree(z, g)
    n = jax.tree.leaves(updates)[0].shape[0]
    dot, zz, gg = jax.vmap(
        lambda i: stats_one(jax.tree.map(lambda u: u[i], updates),
                            jax.tree.map(lambda u: u[i], guides)))(jnp.arange(n))
    mask = diversefl_mask(dot, zz, gg, cfg)
    agg = masked_mean(updates, mask)
    c2 = jnp.sqrt(zz / jnp.maximum(gg, 1e-30))
    return agg, mask, {"dot": dot, "z_norm_sq": zz, "g_norm_sq": gg, "c2": c2}
