"""DiverseFL — the paper's contribution (Sec. III).

Per-client Byzantine mitigation: the server (inside the TEE enclave)
computes, for every participating client j, a *guiding update* Δ̃_j by
running the same E local-SGD steps on the small sample M_j^0 the client
shared once before training.  The client's uploaded update z_j is kept
iff both similarity conditions hold:

    C1 = sign(Δ̃_j · z_j)            C1 > ε1            (direction, Eq. 2/4)
    C2 = ‖z_j‖₂ / ‖Δ̃_j‖₂            ε2 < C2 < ε3        (length,   Eq. 3/5)

and the global model is updated with the plain mean of surviving updates
(Eq. 6).  Paper defaults: (ε1, ε2, ε3) = (0, 0.5, 2).

This module is the single source of truth for the criterion: the mask
(`diversefl_mask`), the similarity statistics (pytree / stacked-matrix)
and the masked aggregation (Eq. 6) are defined once here and imported by
every execution layer:
  * fl/server.py — the SecureServer + aggregator registry every
    simulator round routes through (DESIGN.md §3);
  * kernels/similarity.py + kernels/masked_agg.py — fused Pallas
    twins of the same math (one HBM pass each), used on TPU;

At pod scale the same criterion runs inside the sharded FL round step
(launch/train.py): each client's (dot, ‖z‖², ‖Δ̃‖²) is reduced
shard-locally and psum'd over the ``model`` axis, so per-client updates
are never materialized N-fold.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DiverseFLConfig:
    eps1: float = 0.0     # direction threshold: require dot > eps1 (sign test)
    eps2: float = 0.5     # length ratio lower bound
    eps3: float = 2.0     # length ratio upper bound
    local_steps: int = 1  # E
    sample_frac: float = 0.01


# ----------------------------------------------------------------------
# Similarity statistics
# ----------------------------------------------------------------------

def similarity_stats(z: jnp.ndarray, g: jnp.ndarray):
    """Flat-vector stats: (z·g, ‖z‖², ‖g‖²) in fp32."""
    z = z.astype(jnp.float32)
    g = g.astype(jnp.float32)
    return jnp.vdot(z, g), jnp.vdot(z, z), jnp.vdot(g, g)


def _tree_vdot(a_tree, b_tree):
    """Elementwise-multiply + per-leaf reduce, summed across leaves (fp32).

    Deliberately NOT jnp.vdot: vdot flattens its operands to 1-D, which
    defeats GSPMD sharding propagation when the leaves are sharded over a
    ``model`` axis and forces a full all-gather of every update leaf.
    Per-leaf elementwise products keep the partial sums shard-local, so
    the same function serves the simulator and the pod-scale round step
    (launch/train.py, §Perf A2)."""
    parts = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        a_tree, b_tree)
    return jnp.sum(jnp.stack(jax.tree.leaves(parts)))


def similarity_stats_tree(z_tree, g_tree):
    """Pytree stats: (z·g, ‖z‖², ‖g‖²), exact fp32, shard-local partials."""
    return (_tree_vdot(z_tree, g_tree), _tree_vdot(z_tree, z_tree),
            _tree_vdot(g_tree, g_tree))


def similarity_stats_matrix(U, G):
    """Stacked-matrix stats: U, G (N, D) -> per-client (dot, ‖z‖², ‖g‖²)."""
    U = U.astype(jnp.float32)
    G = G.astype(jnp.float32)
    return jnp.sum(U * G, axis=1), jnp.sum(U * U, axis=1), jnp.sum(G * G, axis=1)


def diversefl_mask(dot, z_sq, g_sq, cfg: DiverseFLConfig):
    """Boolean keep-mask from per-client stats (any shape, elementwise).

    Condition 1: C1 = sign(Δ̃·z): kept iff dot > eps1 (eps1=0 reproduces the
    paper's sign test).  Condition 2: eps2 < ‖z‖/‖Δ̃‖ < eps3, evaluated in
    squared form to avoid sqrt of tiny values.
    """
    c1 = dot > cfg.eps1
    ratio_sq = z_sq / jnp.maximum(g_sq, 1e-30)
    c2 = (ratio_sq > cfg.eps2 ** 2) & (ratio_sq < cfg.eps3 ** 2)
    return c1 & c2


def c2_ratio(z_sq, g_sq):
    """C2 = ‖z‖/‖Δ̃‖ from the squared norms (Eq. 3/5)."""
    return jnp.sqrt(z_sq / jnp.maximum(g_sq, 1e-30))


def criterion_logs(dot, z_sq, g_sq):
    """Per-client criterion diagnostics shared by every round-step layer:
    C1 = sign(Δ̃·z), C2 = ‖z‖/‖Δ̃‖, and their product (Fig. 2's y-axis)."""
    c1 = jnp.sign(dot)
    c2 = c2_ratio(z_sq, g_sq)
    return {"c1": c1, "c2": c2, "c1c2": c1 * c2}


# ----------------------------------------------------------------------
# Guiding update (enclave Step 3)
# ----------------------------------------------------------------------

def guiding_update(params, guide_batch, grad_fn: Callable, lr, E: int = 1):
    """Δ̃ = θ - SGD_E(θ; M^0): E gradient-descent steps on the enclave sample.

    grad_fn(params, batch) -> grad pytree.  Mirrors the client's local
    optimizer exactly (plain SGD, same lr, same E) per Algorithm 1.
    """
    theta = params

    def step(theta, _):
        g = grad_fn(theta, guide_batch)
        # trailing astype: dtype-stable scan carry for bf16 zoo params
        # (f32 lr promotes the product); identity for f32 small models
        theta = jax.tree.map(
            lambda t, gg: (t - lr * gg.astype(t.dtype)).astype(t.dtype),
            theta, g)
        return theta, None

    theta, _ = jax.lax.scan(step, theta, None, length=E)
    return jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), params, theta)


# ----------------------------------------------------------------------
# Aggregation (Eq. 6)
# ----------------------------------------------------------------------

def masked_sum_fold(U, w):
    """Ordered weighted sum over the client axis: a strict left fold
    (client 0 first, one ``s + u_i * w_i`` per client via ``lax.scan``).

    XLA's native axis-0 reduction associates however the backend
    vectorizes, so its bits change with the memory layout; the fold fixes
    one canonical association, making Eq. 6 *bitwise independent of how
    the client axis is executed* — unchunked, chunked, or streamed one
    block at a time (fl/streaming.py folds its AggState in exactly this
    order).  ``unroll`` cuts the while-loop overhead without touching
    the operation order — same adds, same bits, *for the 0/1 mask
    weights this fold is used with*: their products are exact, so the
    FMA an unrolled multiply-add chain may or may not compile to cannot
    change a bit.  Real-valued weights lose that immunity (solo and
    vmapped lowerings pick FMA differently) — rules folding real
    weights must unroll=1 instead (core/aggregators.fltrust,
    DESIGN.md §8).  Cost profile: at model-scale
    D (~34k, fp32) the single streamed pass over U beats the
    ``(U * m[:, None]).sum(0)`` materialize-then-reduce it replaced
    (~14.9 ms vs ~150 ms at N=1024 on this CPU), while at toy dimensions
    the loop trip count adds per-round overhead — determinism across
    execution layouts, not speed, is what this function buys.  Returns
    ``(sum (D,), total weight)`` in fp32.
    """
    U = U.astype(jnp.float32)
    w = w.astype(jnp.float32)

    def step(carry, uw):
        u, wi = uw
        s, n = carry
        return (s + u * wi, n + wi), None

    init = (jnp.zeros(U.shape[1:], jnp.float32), jnp.float32(0.0))
    (s, n), _ = jax.lax.scan(step, init, (U, w), unroll=8)
    return s, n


def masked_mean_flat(U, mask):
    """Stacked-matrix Eq. 6: U (N, D), mask (N,) -> (D,) fp32 masked mean.

    The single source of truth for the masked aggregation the simulator,
    the registry's ``oracle``/``diversefl`` rules and the kernel oracle
    all share; kernels/masked_agg.py is its one-HBM-pass Pallas twin.
    Reduces via ``masked_sum_fold``, so the result matches the streaming
    AggState path bit-for-bit (DESIGN.md §6)."""
    s, n = masked_sum_fold(U, mask)
    return s / jnp.maximum(n, 1.0)


def masked_mean(updates, mask):
    """updates: pytree with leading client dim N; mask: (N,) bool/float."""
    m = mask.astype(jnp.float32)
    denom = jnp.maximum(m.sum(), 1.0)

    def agg(u):
        mm = m.reshape((-1,) + (1,) * (u.ndim - 1))
        return (u.astype(jnp.float32) * mm).sum(0) / denom
    return jax.tree.map(agg, updates)


def diversefl_aggregate(updates, guides, cfg: DiverseFLConfig):
    """Full Step 4+5 at simulator scale.

    updates/guides: pytrees whose leaves have leading client dim N.
    Returns (aggregated update pytree, keep mask (N,), stats dict)."""
    def stats_one(z, g):
        return similarity_stats_tree(z, g)
    n = jax.tree.leaves(updates)[0].shape[0]
    dot, zz, gg = jax.vmap(
        lambda i: stats_one(jax.tree.map(lambda u: u[i], updates),
                            jax.tree.map(lambda u: u[i], guides)))(jnp.arange(n))
    mask = diversefl_mask(dot, zz, gg, cfg)
    agg = masked_mean(updates, mask)
    return agg, mask, {"dot": dot, "z_norm_sq": zz, "g_norm_sq": gg,
                       "c2": c2_ratio(zz, gg)}
