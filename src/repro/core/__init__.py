from .diversefl import (DiverseFLConfig, similarity_stats, similarity_stats_tree,
                        diversefl_mask, guiding_update, masked_mean,
                        diversefl_aggregate)
from . import aggregators, attacks, tee, sample_filter
