from .diversefl import (DiverseFLConfig, similarity_stats, similarity_stats_tree,
                        similarity_stats_matrix, diversefl_mask, c2_ratio,
                        criterion_logs, guiding_update, masked_mean,
                        masked_mean_flat, masked_sum_fold, diversefl_aggregate)
from . import aggregators, attacks, tee, sample_filter
