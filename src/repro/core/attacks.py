"""Byzantine fault / attack models (Sec. IV).

Update-level (model poisoning) attacks transform the would-be-honest
update z_j; data-level attacks (label flip, backdoor) transform the
client's local batch before training.  ``scale`` implements the model
replacement attack of Bagdasaryan et al. [45] used for the backdoor.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    kind: str = "none"        # none|gaussian|sign_flip|same_value|label_flip|backdoor
    sigma: float = 1e4        # gaussian / same-value magnitude
    scale: float = 5.0        # backdoor model-replacement factor
    source_class: int = 3     # backdoor: relabel source -> target
    target_class: int = 4


UPDATE_ATTACKS = ("gaussian", "sign_flip", "same_value", "scale")
DATA_ATTACKS = ("label_flip", "backdoor")


def attack_update(update_flat, kind: str, key, cfg: AttackConfig,
                  sigma=None, scale=None):
    """Flat (D,) update -> corrupted flat update.

    ``sigma``/``scale`` override the config's Python constants with
    *traced* values (scalar arrays).  The sweep engine (fl/sweep.py)
    batches runs whose attack magnitudes differ along a vmapped scenario
    axis, and the round engine passes them as jit operands so changing a
    magnitude between runs is a new argument, not a new trace.  ``None``
    falls back to ``cfg`` — bit-identical, since a weak-typed Python
    float and an f32 scalar produce the same f32 arithmetic."""
    sigma = cfg.sigma if sigma is None else sigma
    scale = cfg.scale if scale is None else scale
    if kind == "gaussian":
        return jax.random.normal(key, update_flat.shape,
                                 update_flat.dtype) * sigma
    if kind == "sign_flip":
        return -update_flat
    if kind == "same_value":
        return jnp.full_like(update_flat, sigma)
    if kind in ("backdoor", "scale"):
        # one scaling branch for both names: "backdoor" is the model
        # replacement factor of Bagdasaryan et al. [45] (data already
        # poisoned), "scale" the stealthy x-factor probing the C2 band
        return update_flat * scale
    return update_flat


def attack_update_tree(update, kind: str, key, cfg: AttackConfig):
    leaves, treedef = jax.tree.flatten(update)
    keys = jax.random.split(key, len(leaves))
    out = [attack_update(l.reshape(-1), kind, k, cfg).reshape(l.shape)
           for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def flip_labels(labels, n_classes: int):
    """Label-flip fault: class c -> (n_classes - 1 - c)  (paper: c_n - c)."""
    return (n_classes - 1 - labels).astype(labels.dtype)


def poison_backdoor(x, y, cfg: AttackConfig, frac: float = 0.5):
    """Relabel ~frac of source-class examples to the target class and stamp
    a trigger pattern (corner patch) on them."""
    n = y.shape[0]
    is_src = y == cfg.source_class
    take = jnp.cumsum(is_src) <= jnp.maximum((is_src.sum() * frac).astype(jnp.int32), 1)
    sel = is_src & take
    y2 = jnp.where(sel, cfg.target_class, y)
    if x.ndim >= 3:  # image (N, H, W[, C]): stamp a bright 3x3 corner trigger
        x2 = x.at[:, :3, :3].set(jnp.where(
            sel.reshape((-1,) + (1,) * (x.ndim - 1)), 1.0, x[:, :3, :3]))
    else:
        x2 = x.at[:, :3].set(jnp.where(sel[:, None], 1.0, x[:, :3]))
    return x2, y2


def make_byzantine_mask(n_clients: int, f: int, key=None):
    """Byzantine identities are fixed across rounds (as in the paper).
    Default: evenly spaced over the client index — with the sorted-shard
    non-IID partition this matches the paper's setup where every class
    keeps at least one benign holder.  Pass a key for a random choice."""
    mask = jnp.zeros((n_clients,), bool)
    if f > 0:
        ids = jnp.linspace(0, n_clients - 1, f).round().astype(jnp.int32)
        mask = mask.at[ids].set(True)
    if key is not None:
        mask = jax.random.permutation(key, mask)
    return mask
