"""Step 0/1 — sample-poisoning mitigation (Sec. III-A, IV-C).

The FL administrator pre-trains a clean model on a small known-clean
dataset; each client's shared enclave sample is scored with it, and
clients whose sample accuracy falls below the threshold T are flagged as
poisoned and dropped from training.  All of this executes "inside" the
enclave (core/tee.py).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from ..optim import sgd_step
from .tee import Enclave


@dataclasses.dataclass(frozen=True)
class FilterConfig:
    threshold: float = 0.7      # MNIST setting (CIFAR uses 0.3 in the paper)
    pretrain_steps: int = 300
    pretrain_lr: float = 0.1
    pretrain_batch: int = 64


def pretrain_clean_model(model, clean_x, clean_y, cfg: FilterConfig, key):
    """Train the screening model on the administrator's clean dataset."""
    params = model.init(key)
    n = clean_y.shape[0]

    @jax.jit
    def step(params, k):
        idx = jax.random.randint(k, (min(cfg.pretrain_batch, n),), 0, n)
        g = jax.grad(lambda p: model.loss(p, clean_x[idx], clean_y[idx]))(params)
        new, _ = sgd_step(params, g, (), cfg.pretrain_lr)
        return new

    for i in range(cfg.pretrain_steps):
        key, sub = jax.random.split(key)
        params = step(params, sub)
    return params


def screen_clients(model, pretrained, enclave: Enclave, cfg: FilterConfig):
    """Score every sealed client sample; returns (accepted_ids, accs dict).
    Rejected clients are dropped from the enclave store (paper's basic
    mitigation: drop, with offline human verification as the alternative)."""
    accepted, accs = [], {}
    for cid in list(enclave.client_ids()):
        x, y = enclave.unseal_samples(cid)
        acc = model.accuracy(pretrained, x, y)
        accs[cid] = acc
        if acc >= cfg.threshold:
            accepted.append(cid)
        else:
            enclave.drop_client(cid)
    return accepted, accs
