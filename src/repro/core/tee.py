"""TEE (Intel SGX) enclave simulation.

TPUs (and this CPU container) have no hardware TEE, so there is no
literal port of the paper's SGX enclave — see DESIGN.md §2.  What we keep
is the *system role* the enclave plays, as an explicit trust boundary
object with the same lifecycle and the paper's measured cost model:

  * remote attestation  -> `attest()` produces a measurement/quote record
    that clients verify before sealing data to the enclave
  * sealed sample store -> client samples are stored encrypted
    (keyed-XOR stand-in for AES-GCM; confidentiality is simulated, the
    data-flow discipline is real: plaintext samples are only reachable
    through Enclave methods)
  * EPC memory budget   -> 128 MB; exceeding it models SGX paging costs
  * throughput model    -> Fig. 9: how many clients one enclave supports
    given guiding-update FLOPs vs. edge-client step time

The SecureServer in fl/server.py routes every guiding-update computation,
similarity check and aggregation through an Enclave instance, mirroring
Steps 0–5 of Algorithm 1: guide batches are only ever reachable by
unsealing the client blobs stored here (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

EPC_BYTES = 128 * 2 ** 20          # SGX v1 enclave page cache (paper Sec. IV-D)
PAGE_BYTES = 4096                  # SGX EPC page granularity

# Fig. 9 calibration: client (compute+comm) time relative to the TEE's
# guiding-update time at 1% sampling — "a single TEE can support up to N
# clients" numbers from the paper.
FIG9_CLIENTS_1PCT = {"mnist_softmax": 490, "mnist_3nn": 320,
                     "cifar10_vgg11": 150, "cifar100_vgg11": 119}
FIG9_CLIENTS_3PCT = {"mnist_softmax": 105, "mnist_3nn": 92,
                     "cifar10_vgg11": 45, "cifar100_vgg11": 38}


@dataclasses.dataclass
class AttestationQuote:
    measurement: str           # hash of the enclave code identity
    nonce: int


class Enclave:
    """Software-simulated SGX enclave on the FL server."""

    def __init__(self, code_identity: str = "diversefl-enclave-v1",
                 epc_bytes: int = EPC_BYTES, seed: int = 0):
        self._identity = code_identity
        self._measurement = hashlib.sha256(code_identity.encode()).hexdigest()
        self._seal_key = np.random.default_rng(seed).integers(
            0, 255, size=32, dtype=np.uint8)
        self._store: Dict[int, bytes] = {}
        self._meta: Dict[int, dict] = {}
        self.epc_bytes = epc_bytes
        self.paging_events = 0
        self.seal_version = 0      # bumped on every store mutation (cache key)

    # --- attestation -------------------------------------------------
    def attest(self, nonce: int) -> AttestationQuote:
        return AttestationQuote(self._measurement, nonce)

    @staticmethod
    def verify_quote(quote: AttestationQuote, expected_identity: str,
                     nonce: int) -> bool:
        exp = hashlib.sha256(expected_identity.encode()).hexdigest()
        return quote.measurement == exp and quote.nonce == nonce

    # --- sealed sample store (Step 1) ---------------------------------
    def _xor(self, raw: bytes) -> bytes:
        key = np.frombuffer(
            (self._seal_key.tobytes() * (len(raw) // 32 + 1))[:len(raw)],
            dtype=np.uint8)
        return (np.frombuffer(raw, np.uint8) ^ key).tobytes()

    def seal_samples(self, client_id: int, x, y) -> None:
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.int32)
        blob = x.tobytes() + y.tobytes()
        prev_over = max(0, self.stored_bytes() - self.epc_bytes)
        self._store[client_id] = self._xor(blob)
        self._meta[client_id] = {"x_shape": x.shape, "y_shape": y.shape}
        self.seal_version += 1
        # EPC spillover is paged at 4 KB granularity: each seal that grows
        # the store past the budget costs one paging event per spilled page
        # (the Fig. 9 cost model is proportional to bytes over budget).
        new_over = max(0, self.stored_bytes() - self.epc_bytes)
        if new_over > prev_over:
            self.paging_events += -(-(new_over - prev_over) // PAGE_BYTES)

    def unseal_samples(self, client_id: int):
        blob = self._xor(self._store[client_id])
        meta = self._meta[client_id]
        nx = int(np.prod(meta["x_shape"]))
        x = np.frombuffer(blob[: 4 * nx], np.float32).reshape(meta["x_shape"])
        y = np.frombuffer(blob[4 * nx:], np.int32).reshape(meta["y_shape"])
        return jnp.asarray(x), jnp.asarray(y)

    def stored_bytes(self) -> int:
        return sum(len(b) for b in self._store.values())

    def client_ids(self):
        return sorted(self._store.keys())

    def drop_client(self, client_id: int) -> None:
        self._store.pop(client_id, None)
        self._meta.pop(client_id, None)
        self.seal_version += 1

    # --- throughput model (Fig. 9 / Sec. IV-D) -------------------------
    @staticmethod
    def max_clients(guide_flops: float, client_step_seconds: float,
                    tee_flops_per_s: float = 50e9,
                    model_bytes: int = 0) -> int:
        """How many clients one enclave supports without stalling training:
        the TEE processes clients sequentially (SGX memory limits), so it
        needs N * t_guide <= t_client.  Models fall off a cliff when the
        model doesn't fit EPC (paper: VGG-11 ~3x slowdown)."""
        t_guide = guide_flops / tee_flops_per_s
        if model_bytes > EPC_BYTES:
            t_guide *= 3.0          # paging overhead regime
        if t_guide <= 0:
            return 10 ** 9
        return max(1, int(client_step_seconds / t_guide))
