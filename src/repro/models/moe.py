"""Fine-grained Mixture-of-Experts with shared experts (DeepSeek-MoE style).

Token dispatch is sort-based with a capacity limit (GShard-style dropping,
MaxText-style implementation): no (tokens × experts × capacity) one-hot
tensors are ever materialized, so it scales to 384-expert / 1T-param
configurations.  Expert weights carry an explicit leading expert dim that
the sharding rules map onto the ``model`` mesh axis (expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding import shard
from .config import ModelConfig
from .layers import activation, dense_init, gated, make_mlp_params, apply_mlp


def make_moe_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_expert
    p = {"router": dense_init(ks[0], (D, E), jnp.float32),
         "routed_up": dense_init(ks[1], (E, D, F), cfg.param_dtype, fan_in=D),
         "routed_down": dense_init(ks[2], (E, F, D), cfg.param_dtype, fan_in=F)}
    if gated(cfg.activation):
        p["routed_gate"] = dense_init(ks[3], (E, D, F), cfg.param_dtype, fan_in=D)
    if cfg.n_shared_experts > 0:
        p["shared"] = make_mlp_params(ks[4], cfg,
                                      d_ff=cfg.n_shared_experts * cfg.d_expert)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def apply_moe(x, p, cfg: ModelConfig):
    """x: (B, S, D) -> (out, aux_loss)."""
    B, S, D = x.shape
    E, K, F = cfg.n_experts, cfg.top_k, cfg.d_expert
    t = B * S
    xf = x.reshape(t, D)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)                      # (t, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch/GShard form) ----
    ones = jnp.zeros((t, E), probs.dtype).at[
        jnp.arange(t)[:, None], idx].set(1.0)
    frac_tokens = ones.mean(0)                                # f_e
    frac_probs = probs.mean(0)                                # p_e
    aux = cfg.router_aux_coef * E * jnp.sum(frac_tokens * frac_probs)

    # ---- sort-based dispatch with capacity dropping ----
    # Index-inversion formulation: the only scatters are into small int32/
    # fp32 *index/gate* slot tables; token rows move via a gather whose
    # output is expert-sharded (each shard pulls its own rows from the
    # replicated activations — no (E,C,D)-sized collective), and the
    # combine is a shard-local scatter-add followed by one psum-sized
    # all-reduce of the (t, D) output.  The naive row-scatter variant
    # replicated (E*C, D) fp32 buffers across the mesh (see EXPERIMENTS.md
    # §Perf, kimi iteration A1).
    C = _capacity(t, cfg)
    eids = idx.reshape(-1)                                    # (t*K,)
    order = jnp.argsort(eids)                                 # stable
    sorted_eids = eids[order]
    counts = jax.ops.segment_sum(jnp.ones_like(eids), eids, num_segments=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * K) - starts[sorted_eids]
    keep = pos < C
    dest = jnp.where(keep, sorted_eids * C + jnp.clip(pos, 0, C - 1), E * C)
    tok = order // K                                          # source token

    # slot tables: slot -> source token, slot -> gate (sentinel slot E*C)
    slot_tok = jnp.full((E * C + 1,), t, jnp.int32).at[dest].set(tok)
    slot_gate = jnp.zeros((E * C + 1,), jnp.float32).at[dest].set(
        keep * gates.reshape(-1)[order])

    xf_pad = jnp.concatenate([xf, jnp.zeros((1, D), x.dtype)], axis=0)
    h = xf_pad[slot_tok[:E * C]].reshape(E, C, D)             # gather
    h = shard(h, P("model", None, None))

    up = jnp.einsum("ecd,edf->ecf", h, p["routed_up"])
    if "routed_gate" in p:
        g = activation(jnp.einsum("ecd,edf->ecf", h, p["routed_gate"]),
                       cfg.activation)
        hidden = g * up
    else:
        hidden = activation(up, cfg.activation)
    y = jnp.einsum("ecf,efd->ecd", hidden, p["routed_down"])
    y = shard(y, P("model", None, None))

    contrib = y.reshape(E * C, D) * slot_gate[:E * C, None].astype(y.dtype)
    out = jnp.zeros((t + 1, D), y.dtype).at[slot_tok[:E * C]].add(contrib)[:t]

    if "shared" in p:
        out = out + apply_mlp(xf[:, None, :], p["shared"], cfg)[:, 0, :]
    return out.reshape(B, S, D), aux
