"""Attention: GQA/MQA self-attention (full / sliding-window), cross-attention,
blockwise (flash-style) long-sequence path, and single-token decode with a
KV cache (ring buffer for sliding-window layers).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding import shard
from .config import ModelConfig
from .layers import dense_init

NEG_INF = -2.0 ** 30


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (B, S, H, dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


# ----------------------------------------------------------------------
# Params
# ----------------------------------------------------------------------

def make_attn_params(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    return {"wq": dense_init(ks[0], (d, h * hd), cfg.param_dtype),
            "wk": dense_init(ks[1], (d, kv * hd), cfg.param_dtype),
            "wv": dense_init(ks[2], (d, kv * hd), cfg.param_dtype),
            "wo": dense_init(ks[3], (h * hd, d), cfg.param_dtype, fan_in=h * hd)}


# ----------------------------------------------------------------------
# Core softmax attention on explicit q, k, v
# ----------------------------------------------------------------------

def _sdpa(q, k, v, mask, softcap=None):
    """q: (B,Sq,H,dh)  k,v: (B,Sk,K,dh)  mask: broadcastable (B,1,Sq,Sk) bool."""
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    g = H // K
    qf = q.reshape(B, Sq, K, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    s = s / jnp.sqrt(dh).astype(jnp.float32)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask[:, :, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, dh).astype(v.dtype)


def _causal_mask(q_pos, k_pos, window):
    """q_pos: (B,Sq), k_pos: (B,Sk) -> (B,1,Sq,Sk) bool."""
    m = k_pos[:, None, None, :] <= q_pos[:, None, :, None]
    if window is not None:
        m &= k_pos[:, None, None, :] > (q_pos[:, None, :, None] - window)
    return m


def _blockwise(q, k, v, q_pos, k_pos, window, chunk, softcap=None):
    """Memory-efficient attention: scan over q chunks (the XLA 'flash' path).

    For sliding-window layers each q chunk only loads a (chunk+window) slice
    of k/v, making compute O(S * window) instead of O(S^2).
    """
    B, S, H, dh = q.shape
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = q.shape[1] // chunk
    qc = q.reshape(B, n_chunks, chunk, H, dh).swapaxes(0, 1)
    pc = q_pos.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    use_slice = window is not None and (chunk + window) < k.shape[1]
    span = chunk + window if use_slice else k.shape[1]

    def body(carry, inp):
        i, (qi, pi) = inp
        if use_slice:
            start = jnp.maximum(i * chunk - window, 0)
            start = jnp.minimum(start, k.shape[1] - span)
            ki = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kpi = jax.lax.dynamic_slice_in_dim(k_pos, start, span, axis=1)
        else:
            ki, vi, kpi = k, v, k_pos
        mask = _causal_mask(pi, kpi, window) & (pi[:, None, :, None] >= 0)
        oi = _sdpa(qi, ki, vi, mask, softcap)
        return carry, oi

    _, out = jax.lax.scan(body, None,
                          (jnp.arange(n_chunks), (qc, pc)))
    out = out.swapaxes(0, 1).reshape(B, n_chunks * chunk, H, dh)
    return out[:, :S]


# ----------------------------------------------------------------------
# Self attention block (training / prefill / decode)
# ----------------------------------------------------------------------

def self_attention(x, p, cfg: ModelConfig, positions, window=None,
                   cache=None, cache_index=None):
    """Returns (out, new_cache).  cache: {"k": (B,C,K,dh), "v": ...} or None.

    - cache is None            -> training/forward; new_cache is (k, v) computed.
    - cache given, x is 1 tok  -> decode: update ring/linear cache at cache_index.
    """
    B, S, d = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, K, dh)
    v = (x @ p["wv"]).reshape(B, S, K, dh)
    q = shard(q, P(None, None, "model", None))
    k = rope(k, positions, cfg.rope_theta)
    q = rope(q, positions, cfg.rope_theta)

    if cache is None:
        if cfg.use_kernels and S > cfg.attn_direct_max:
            from ..kernels import ops as kops
            o = kops.flash_attention(q, k, v, window=window,
                                     softcap=cfg.logit_softcap)
        elif S <= cfg.attn_direct_max:
            mask = _causal_mask(positions, positions, window)
            o = _sdpa(q, k, v, mask, cfg.logit_softcap)
        else:
            o = _blockwise(q, k, v, positions, positions, window,
                           cfg.attn_chunk, cfg.logit_softcap)
        new_cache = {"k": k, "v": v}
    else:
        C = cache["k"].shape[1]
        slot = cache_index % C if window is not None else cache_index
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        # key positions: for ring buffers reconstruct absolute positions.
        idx = jnp.arange(C, dtype=jnp.int32)[None, :]
        if window is not None:
            # entry at idx holds the largest p <= cache_index with p % C == idx
            k_pos = cache_index - ((cache_index - idx) % C)
            k_pos = jnp.broadcast_to(k_pos, (B, C))
        else:
            k_pos = jnp.broadcast_to(idx, (B, C))
        valid = (k_pos <= positions[:, :1]) & (k_pos >= 0)
        mask = _causal_mask(positions, k_pos, window) & valid[:, None, None, :]
        o = _sdpa(q, ck, cv, mask, cfg.logit_softcap)
        new_cache = {"k": ck, "v": cv}

    out = o.reshape(B, S, H * dh) @ p["wo"]
    return out, new_cache


# ----------------------------------------------------------------------
# Cross attention (VLM image layers, Whisper enc-dec)
# ----------------------------------------------------------------------

def cross_attention(x, p, cfg: ModelConfig, cross_kv):
    """cross_kv: {"k": (B,L,K,dh), "v": (B,L,K,dh)} (precomputed from the
    frontend embeddings or encoder output; static during decode)."""
    B, S, d = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    q = shard(q, P(None, None, "model", None))
    L = cross_kv["k"].shape[1]
    mask = jnp.ones((B, 1, S, L), bool)
    o = _sdpa(q, cross_kv["k"], cross_kv["v"], mask, cfg.logit_softcap)
    return o.reshape(B, S, H * dh) @ p["wo"]


def make_cross_kv(emb, p, cfg: ModelConfig):
    """Project frontend/encoder embeddings once into cross K/V."""
    B, L, _ = emb.shape
    K, dh = cfg.n_kv_heads, cfg.head_dim
    k = (emb @ p["wk"]).reshape(B, L, K, dh)
    v = (emb @ p["wv"]).reshape(B, L, K, dh)
    return {"k": k, "v": v}
