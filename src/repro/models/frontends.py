"""Modality frontend stubs (the one sanctioned carve-out).

The [audio] and [vlm] architectures specify the transformer backbone; the
mel-spectrogram + conv feature extractor (Whisper) and the ViT/SigLIP
vision encoder + projector (Llama-3.2-Vision) are stubbed: these helpers
produce embedding tensors of the correct shape/dtype that stand in for
the frontend outputs, and add the sinusoidal positions the real frontends
would provide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def sinusoidal(length: int, dim: int, dtype=jnp.float32):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def audio_frames(key, batch: int, cfg: ModelConfig):
    """Stub for mel-spectrogram + conv1d stack: (B, enc_seq, d_model)."""
    emb = jax.random.normal(key, (batch, cfg.enc_seq, cfg.d_model),
                            jnp.float32) * 0.02
    return (emb + sinusoidal(cfg.enc_seq, cfg.d_model)).astype(cfg.dtype)


def vision_patches(key, batch: int, cfg: ModelConfig):
    """Stub for ViT encoder + projector: (B, n_patches, d_model)."""
    emb = jax.random.normal(key, (batch, cfg.n_patches, cfg.d_model),
                            jnp.float32) * 0.02
    return (emb + sinusoidal(cfg.n_patches, cfg.d_model)).astype(cfg.dtype)
