"""Model assembly: embedding, scanned block groups, loss, prefill & decode.

Params layout::

  {"embed": (V, D),
   "prelude": (first_k_dense blocks, unstacked),
   "groups": tuple(len(layout)) of block trees, leaves lead with n_groups,
   "final_norm": {...},
   "encoder": {"groups": ..., "final_norm": ...}        # enc-dec only
  }

Layer stacking uses ``jax.lax.scan`` over groups so compile time and HLO
size are independent of depth (61-layer / 100-layer configs lower in
seconds).  Activation checkpointing (``cfg.remat``) wraps the group body.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding import shard
from .attention import (cross_attention, make_attn_params, make_cross_kv,
                        self_attention)
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, dense_init, make_mlp_params,
                     make_norm_params)
from .mamba import init_mamba_cache, make_mamba_params, mamba_mixer
from .moe import apply_moe, make_moe_params


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def _make_block_params(key, cfg: ModelConfig, entry, force_mlp=False):
    mixer, ffn = entry
    ks = jax.random.split(key, 6)
    p = {"ln1": make_norm_params(ks[0], cfg)}
    if mixer in ("attn", "swa"):
        p["attn"] = make_attn_params(ks[1], cfg)
    elif mixer == "mamba":
        p["mamba"] = make_mamba_params(ks[1], cfg)
    elif mixer == "xattn":
        p["xattn"] = make_attn_params(ks[1], cfg, cross=True)
        p["xgate"] = jnp.zeros((), jnp.float32)
    elif mixer == "attn_x":
        p["attn"] = make_attn_params(ks[1], cfg)
        p["ln_x"] = make_norm_params(ks[2], cfg)
        p["xattn"] = make_attn_params(ks[3], cfg, cross=True)
    else:
        raise ValueError(mixer)
    if force_mlp:
        ffn = "mlp"
    if ffn == "mlp":
        p["ln2"] = make_norm_params(ks[4], cfg)
        p["mlp"] = make_mlp_params(ks[5], cfg)
    elif ffn == "moe":
        p["ln2"] = make_norm_params(ks[4], cfg)
        p["moe"] = make_moe_params(ks[5], cfg)
    elif ffn != "none":
        raise ValueError(ffn)
    return p


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    params = {"embed": dense_init(ks[0], (cfg.padded_vocab, cfg.d_model),
                                  cfg.param_dtype, fan_in=cfg.d_model),
              "final_norm": make_norm_params(ks[1], cfg)}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[5], (cfg.d_model, cfg.padded_vocab),
                                       cfg.param_dtype)

    if cfg.first_k_dense:
        pk = jax.random.split(ks[2], cfg.first_k_dense)
        params["prelude"] = [
            _make_block_params(pk[i], cfg, ("attn", "mlp"))
            for i in range(cfg.first_k_dense)]

    gk = jax.random.split(ks[3], cfg.n_groups)

    def one_group(k):
        eks = jax.random.split(k, len(cfg.layout))
        return tuple(_make_block_params(eks[i], cfg, e)
                     for i, e in enumerate(cfg.layout))

    params["groups"] = jax.vmap(one_group)(gk)

    if cfg.is_enc_dec:
        ek = jax.random.split(ks[4], cfg.n_enc_layers + 1)

        def one_enc(k):
            return (_make_block_params(k, cfg, ("attn", "mlp")),)
        params["encoder"] = {
            "groups": jax.vmap(one_enc)(ek[:-1]),
            "final_norm": make_norm_params(ek[-1], cfg)}
    return params


# ----------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------

def _run_block(x, bp, entry, cfg: ModelConfig, positions, cross_emb,
               cache, cache_index):
    mixer, ffn = entry
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(x, bp["ln1"], cfg)
    new_cache = None
    if mixer in ("attn", "swa"):
        window = cfg.window if mixer == "swa" else None
        o, kv = self_attention(h, bp["attn"], cfg, positions, window,
                               cache=cache, cache_index=cache_index)
        new_cache = kv
    elif mixer == "mamba":
        o, new_cache = mamba_mixer(h, bp["mamba"], cfg, cache, cache_index)
    elif mixer == "xattn":
        kv = cache["cross"] if cache is not None else \
            make_cross_kv(cross_emb, bp["xattn"], cfg)
        o = cross_attention(h, bp["xattn"], cfg, kv)
        o = o * jnp.tanh(bp["xgate"]).astype(o.dtype)
        new_cache = {"cross": kv}
    elif mixer == "attn_x":
        o1, kv_self = self_attention(
            h, bp["attn"], cfg, positions, None,
            cache=None if cache is None else cache["self"],
            cache_index=cache_index)
        x = x + o1
        h2 = apply_norm(x, bp["ln_x"], cfg)
        kv = cache["cross"] if cache is not None else \
            make_cross_kv(cross_emb, bp["xattn"], cfg)
        o = cross_attention(h2, bp["xattn"], cfg, kv)
        new_cache = {"self": kv_self, "cross": kv}
    else:
        raise ValueError(mixer)
    x = x + o

    if ffn in ("mlp", "moe") or (ffn == "none" and "mlp" in bp):
        h = apply_norm(x, bp["ln2"], cfg)
        if "moe" in bp:
            f, aux = apply_moe(h, bp["moe"], cfg)
        else:
            f = apply_mlp(h, bp["mlp"], cfg)
        x = x + f
    return x, new_cache, aux


def _scan_groups(x, groups, cfg: ModelConfig, positions, cross_emb,
                 cache, cache_index, decode, collect_cache=False):
    def gfn(carry, xs):
        xc, aux = carry
        gp, gc = xs
        new_gc = []
        for li, entry in enumerate(cfg.layout):
            c_in = None if gc is None else gc[li]
            xc, nc, a = _run_block(xc, gp[li], entry, cfg, positions,
                                   cross_emb, c_in, cache_index)
            new_gc.append(nc)
            aux = aux + a
        ys = tuple(new_gc) if (decode or collect_cache) else None
        return (xc, aux), ys

    body = gfn
    if cfg.remat and not decode:
        body = jax.checkpoint(
            gfn, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (groups, cache))
    return x, aux, new_cache


# ----------------------------------------------------------------------
# Encoder (enc-dec archs; non-causal self attention over frame embeddings)
# ----------------------------------------------------------------------

def _encode(params, cfg: ModelConfig, enc_emb):
    B, L, D = enc_emb.shape
    x = enc_emb
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def gfn(carry, gp):
        xc, _ = carry
        bp = gp[0]
        h = apply_norm(xc, bp["ln1"], cfg)
        # non-causal self attention: window=None, mask=all-valid
        from .attention import _sdpa
        H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = (h @ bp["attn"]["wq"]).reshape(B, L, H, dh)
        k = (h @ bp["attn"]["wk"]).reshape(B, L, K, dh)
        v = (h @ bp["attn"]["wv"]).reshape(B, L, K, dh)
        mask = jnp.ones((B, 1, L, L), bool)
        o = _sdpa(q, k, v, mask, cfg.logit_softcap)
        xc = xc + o.reshape(B, L, H * dh) @ bp["attn"]["wo"]
        h2 = apply_norm(xc, bp["ln2"], cfg)
        xc = xc + apply_mlp(h2, bp["mlp"], cfg)
        return (xc, carry[1]), None

    body = jax.checkpoint(gfn) if cfg.remat else gfn
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["encoder"]["groups"])
    return apply_norm(x, params["encoder"]["final_norm"], cfg)


# ----------------------------------------------------------------------
# Forward / prefill
# ----------------------------------------------------------------------

def apply(params, cfg: ModelConfig, tokens, *, enc_emb=None, cross_emb=None,
          positions=None, want_cache=False):
    """Full-sequence forward.  Returns dict(hidden, aux, cache?)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.norm == "rmsnorm":
        x = x * jnp.sqrt(cfg.d_model).astype(cfg.dtype)

    if cfg.is_enc_dec:
        assert enc_emb is not None, "enc-dec arch needs enc_emb"
        cross_emb = _encode(params, cfg, enc_emb.astype(cfg.dtype))
    elif cross_emb is not None:
        cross_emb = cross_emb.astype(cfg.dtype)

    aux_total = jnp.zeros((), jnp.float32)
    prelude_cache = []
    for bp in params.get("prelude", []):
        x, nc, a = _run_block(x, bp, ("attn", "mlp"), cfg, positions,
                              cross_emb, None, None)
        prelude_cache.append(nc)
        aux_total += a

    x, aux, cache = _scan_groups(x, params["groups"], cfg, positions,
                                 cross_emb, None, None, decode=False,
                                 collect_cache=want_cache)
    aux_total += aux
    x = apply_norm(x, params["final_norm"], cfg)
    out = {"hidden": x, "aux": aux_total}
    if want_cache:
        out["cache"] = {"prelude": prelude_cache, "groups": cache}
    return out


def _mask_pad_logits(lg, cfg: ModelConfig):
    if cfg.padded_vocab == cfg.vocab_size:
        return lg
    col = jnp.arange(cfg.padded_vocab)
    return jnp.where(col < cfg.vocab_size, lg, -1e30)


def logits(params, cfg: ModelConfig, hidden):
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    return _mask_pad_logits(
        hidden.astype(jnp.float32) @ w.astype(jnp.float32), cfg)


# ----------------------------------------------------------------------
# Loss: chunked vocab-sharded cross entropy (never materializes full logits)
# ----------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, hidden, targets, mask):
    """hidden: (B,S,D); targets/mask: (B,S)."""
    B, S, D = hidden.shape
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T                               # (D, V)
    T = B * S
    h = hidden.reshape(T, D)
    t = targets.reshape(T)
    m = mask.reshape(T).astype(jnp.float32)
    Q = min(cfg.loss_chunk, T)
    pad = (-T) % Q
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        t = jnp.pad(t, ((0, pad),))
        m = jnp.pad(m, ((0, pad),))
    n = h.shape[0] // Q

    def body(acc, xs):
        hc, tc, mc = xs
        lg = hc.astype(jnp.float32) @ w.astype(jnp.float32)  # (Q, V)
        lg = shard(lg, P(None, "model"))
        lg = _mask_pad_logits(lg, cfg)
        lse = jax.nn.logsumexp(lg, axis=-1)
        correct = jnp.take_along_axis(lg, tc[:, None], axis=-1)[:, 0]
        return acc + jnp.sum((lse - correct) * mc), None

    xs = (h.reshape(n, Q, D), t.reshape(n, Q), m.reshape(n, Q))
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    return total / jnp.maximum(m.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: {"tokens": (B,S), optional "enc_emb"/"cross_emb"/"mask"}."""
    tokens = batch["tokens"]
    out = apply(params, cfg, tokens,
                enc_emb=batch.get("enc_emb"),
                cross_emb=batch.get("cross_emb"))
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(tokens)
    mask = mask.at[:, -1].set(0)
    return lm_loss(params, cfg, out["hidden"], targets, mask) + out["aux"]


# ----------------------------------------------------------------------
# Decode (single token against a cache)
# ----------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Cache pytree matching the layout (leaves lead with n_groups)."""
    K, dh = cfg.n_kv_heads, cfg.head_dim

    def entry_cache(entry, stacked: bool):
        mixer, _ = entry
        lead = (cfg.n_groups,) if stacked else ()

        def z(*shape, dtype=None):
            return jnp.zeros(lead + shape, dtype or cfg.dtype)
        if mixer in ("attn", "swa"):
            C = cache_len if mixer == "attn" else min(cfg.window, cache_len)
            return {"k": z(batch, C, K, dh), "v": z(batch, C, K, dh)}
        if mixer == "mamba":
            return {"conv": z(batch, cfg.ssm_conv, cfg.d_inner),
                    "ssm": z(batch, cfg.d_inner, cfg.ssm_state,
                             dtype=jnp.float32)}
        if mixer == "xattn":
            return {"cross": {"k": z(batch, cfg.cross_len, K, dh),
                              "v": z(batch, cfg.cross_len, K, dh)}}
        if mixer == "attn_x":
            return {"self": {"k": z(batch, cache_len, K, dh),
                             "v": z(batch, cache_len, K, dh)},
                    "cross": {"k": z(batch, cfg.cross_len, K, dh),
                              "v": z(batch, cfg.cross_len, K, dh)}}
        raise ValueError(mixer)

    cache = {"groups": tuple(entry_cache(e, True) for e in cfg.layout)}
    if cfg.first_k_dense:
        cache["prelude"] = [entry_cache(("attn", "mlp"), False)
                            for _ in range(cfg.first_k_dense)]
    return cache


def decode_step(params, cfg: ModelConfig, token, cache, cache_index):
    """token: (B,1) int32; cache_index: () int32 absolute position.

    Returns (logits (B,1,V), new_cache)."""
    B = token.shape[0]
    positions = jnp.broadcast_to(
        cache_index.astype(jnp.int32), (B, 1))
    x = params["embed"][token].astype(cfg.dtype)
    if cfg.norm == "rmsnorm":
        x = x * jnp.sqrt(cfg.d_model).astype(cfg.dtype)

    new_prelude = []
    for bp, pc in zip(params.get("prelude", []), cache.get("prelude", [])):
        x, nc, _ = _run_block(x, bp, ("attn", "mlp"), cfg, positions,
                              None, pc, cache_index)
        new_prelude.append(nc)

    x, _, new_groups = _scan_groups(x, params["groups"], cfg, positions,
                                    None, cache["groups"], cache_index,
                                    decode=True)
    x = apply_norm(x, params["final_norm"], cfg)
    lg = logits(params, cfg, x)
    lg = shard(lg, P(None, None, "model"))
    new_cache = {"groups": new_groups}
    if new_prelude:
        new_cache["prelude"] = new_prelude
    return lg, new_cache
