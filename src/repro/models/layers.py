"""Shared layer primitives: norms, activations, MLPs, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding import shard
from .config import ModelConfig


def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def make_norm_params(key, cfg: ModelConfig, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), cfg.param_dtype),
                "bias": jnp.zeros((d,), cfg.param_dtype)}
    return {"scale": jnp.zeros((d,), cfg.param_dtype)}  # rmsnorm: (1 + scale)


def apply_norm(x, p, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def activation(x, kind: str):
    if kind in ("swiglu",):
        return jax.nn.silu(x)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(kind)


def gated(kind: str) -> bool:
    return kind in ("swiglu", "geglu")


# ----------------------------------------------------------------------
# Dense MLP (gated or plain)
# ----------------------------------------------------------------------

def make_mlp_params(key, cfg: ModelConfig, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    p = {"w_up": dense_init(keys[0], (cfg.d_model, d_ff), cfg.param_dtype),
         "w_down": dense_init(keys[1], (d_ff, cfg.d_model), cfg.param_dtype)}
    if gated(cfg.activation):
        p["w_gate"] = dense_init(keys[2], (cfg.d_model, d_ff), cfg.param_dtype)
    return p


def apply_mlp(x, p, cfg: ModelConfig):
    up = x @ p["w_up"]
    up = shard(up, P(None, None, "model"))
    if "w_gate" in p:
        gate = activation(x @ p["w_gate"], cfg.activation)
        h = gate * up
    else:
        h = activation(up, cfg.activation)
    out = h @ p["w_down"]
    return out
