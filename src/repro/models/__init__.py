from .config import ModelConfig
from . import model, frontends
from .model import (init, apply, loss_fn, lm_loss, logits, init_cache,
                    decode_step)
