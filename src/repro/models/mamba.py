"""Mamba-1 selective-state-space block (Falcon-Mamba / Jamba mixer).

Training path: depthwise causal conv + chunked selective scan — a
``lax.scan`` over sequence chunks carrying the SSM state, with a parallel
associative scan inside each chunk.  The chunking bounds the peak
(B, chunk, d_inner, d_state) working set so 500k-token sequences fit HBM;
the Pallas kernel (kernels/mamba_scan.py) is the VMEM-tiled version of
the same schedule.

Decode path: O(1) per step carrying (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..sharding import shard
from .config import ModelConfig
from .layers import dense_init

SCAN_CHUNK = 256


def make_mamba_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    D, di, R, S, dc = (cfg.d_model, cfg.d_inner, cfg.dt_rank,
                       cfg.ssm_state, cfg.ssm_conv)
    A = jnp.tile(jnp.arange(1, S + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di), cfg.param_dtype),
        "conv_w": dense_init(ks[1], (dc, di), cfg.param_dtype, fan_in=dc),
        "conv_b": jnp.zeros((di,), cfg.param_dtype),
        "x_proj": dense_init(ks[2], (di, R + 2 * S), cfg.param_dtype),
        "dt_proj": dense_init(ks[3], (R, di), cfg.param_dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,), jnp.float32,
                                        1e-3, 1e-1), 1e-4))).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, D), cfg.param_dtype, fan_in=di),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds.  x: (B,S,di), w: (dc,di)."""
    dc = w.shape[0]
    out = x * w[-1]
    for j in range(1, dc):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j, :]
        out = out + shifted * w[dc - 1 - j]
    return out + b


def _ssm_coeffs(xc, p, cfg: ModelConfig):
    """xc: (B,S,di) post-conv activations -> (deltaA, deltaBx, Cmat)."""
    R, S_st = cfg.dt_rank, cfg.ssm_state
    proj = xc @ p["x_proj"]                                   # (B,S,R+2S)
    dt_r, Bm, Cm = jnp.split(proj, [R, R + S_st], axis=-1)
    dt = jax.nn.softplus(dt_r.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"])                      # (B,S,di)
    A = -jnp.exp(p["A_log"])                                  # (di,S_st)
    dA = jnp.exp(dt[..., None] * A)                           # (B,S,di,S_st)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * \
        Bm[:, :, None, :].astype(jnp.float32)                 # (B,S,di,S_st)
    return dA, dBx, Cm.astype(jnp.float32)


def _chunk_scan(dA, dBx, h0):
    """Associative scan within a chunk given entry state h0.

    h_t = dA_t * h_{t-1} + dBx_t ;  returns (h_all (B,Q,di,S), h_last)."""
    def combine(a, b):
        return a[0] * b[0], b[0] * a[1] + b[1]
    A_acc, B_acc = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = A_acc * h0[:, None] + B_acc
    return h_all, h_all[:, -1]


def mamba_mixer(x, p, cfg: ModelConfig, cache=None, cache_index=None):
    """x: (B,S,D).  Returns (out, new_cache).

    cache: {"conv": (B,dc,di), "ssm": (B,di,S_st)} for decode, else None.
    """
    B, S, D = x.shape
    di, dc, S_st = cfg.d_inner, cfg.ssm_conv, cfg.ssm_state
    xz = x @ p["in_proj"]
    xz = shard(xz, P(None, None, "model"))
    xp, z = jnp.split(xz, 2, axis=-1)

    if cache is None:
        xc = jax.nn.silu(_causal_conv(xp, p["conv_w"], p["conv_b"]))
        if cfg.use_kernels:
            from ..kernels import ops as kops
            y = kops.mamba_scan(xc, p, cfg)
        else:
            dA, dBx, Cm = _ssm_coeffs(xc, p, cfg)
            Q = min(SCAN_CHUNK, S)
            pad = (-S) % Q
            if pad:
                dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                             constant_values=1.0)
                dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
            n = dA.shape[1] // Q

            def body(h, xs):
                dA_c, dBx_c = xs
                h_all, h_last = _chunk_scan(dA_c, dBx_c, h)
                return h_last, h_all

            dA_c = dA.reshape(B, n, Q, di, S_st).swapaxes(0, 1)
            dBx_c = dBx.reshape(B, n, Q, di, S_st).swapaxes(0, 1)
            h0 = jnp.zeros((B, di, S_st), jnp.float32)
            h_last, h_seq = jax.lax.scan(body, h0, (dA_c, dBx_c))
            h_seq = h_seq.swapaxes(0, 1).reshape(B, n * Q, di, S_st)[:, :S]
            y = jnp.einsum("bsdn,bsn->bsd", h_seq, Cm)
        y = y + p["D_skip"] * xc.astype(jnp.float32)
        # final states for prefill-style cache handoff
        conv_state = jnp.pad(xp, ((0, 0), (max(dc - S, 0), 0), (0, 0)))[:, -dc:, :]
        if cfg.use_kernels:
            ssm_state = jnp.zeros((B, di, S_st), jnp.float32)  # kernel path: no state export
        else:
            ssm_state = h_last
        new_cache = {"conv": conv_state, "ssm": ssm_state}
    else:
        # single-token decode
        conv_state = jnp.concatenate([cache["conv"][:, 1:, :], xp], axis=1)
        xc = jax.nn.silu(
            jnp.einsum("bcd,cd->bd", conv_state.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32))
            + p["conv_b"].astype(jnp.float32))[:, None, :].astype(x.dtype)
        dA, dBx, Cm = _ssm_coeffs(xc, p, cfg)
        h = dA[:, 0] * cache["ssm"] + dBx[:, 0]               # (B,di,S_st)
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None, :]
        y = y + p["D_skip"] * xc.astype(jnp.float32)
        new_cache = {"conv": conv_state, "ssm": h}

    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return {"conv": jnp.zeros((batch, cfg.ssm_conv, cfg.d_inner), cfg.dtype),
            "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32)}
