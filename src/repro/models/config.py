"""Model configuration for the repro transformer zoo.

A single ``ModelConfig`` describes every architecture family we support:
dense decoders (MHA/GQA/MQA, optional sliding window), fine-grained MoE,
Mamba-1 SSMs, hybrid (Jamba-style) stacks, encoder-decoder (Whisper
backbone) and VLM decoders with interleaved cross-attention.

Layers are described by a repeating ``layout`` *group*: a tuple of
``(mixer, ffn)`` pairs.  ``n_layers`` must be ``first_k_dense +
n_groups * len(layout)``.  Mixers:

  - ``attn``    causal self attention (GQA; ``window`` applies if set)
  - ``swa``     sliding-window causal self attention (forces ``window``)
  - ``mamba``   Mamba-1 selective-scan block
  - ``xattn``   cross-attention block (VLM image layers, attends to
                precomputed patch/frame embeddings)
  - ``attn_x``  self attention followed by cross attention in the same
                block (classic transformer-decoder layer, Whisper)

FFN kinds: ``mlp`` (gated or plain), ``moe`` (fine-grained, optional
shared experts) or ``none`` (block has no separate FFN, e.g. Mamba-only
stacks).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

Mixer = str
Ffn = str
LayoutEntry = Tuple[Mixer, Ffn]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default: d_model // n_heads
    layout: Tuple[LayoutEntry, ...] = (("attn", "mlp"),)
    first_k_dense: int = 0                  # leading unscanned dense-MLP attn layers (DeepSeek/Kimi)
    activation: str = "swiglu"              # swiglu | geglu | gelu | relu
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    window: Optional[int] = None            # sliding-window size for swa mixers
    logit_softcap: Optional[float] = None

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: Optional[int] = None          # fine-grained expert hidden dim (defaults d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba-1) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: Optional[int] = None           # default ceil(d_model / 16)

    # --- encoder (enc-dec archs; None => decoder-only) ---
    n_enc_layers: int = 0
    enc_seq: int = 1500                     # precomputed frame-embedding length (Whisper 30s)

    # --- modality frontend stub ---
    frontend: Optional[str] = None          # None | "audio" | "vision"
    n_patches: int = 1600                   # VLM precomputed patch embeddings per example

    # --- numerics / implementation ---
    dtype: str = "bfloat16"                 # activation / param compute dtype
    param_dtype: str = "bfloat16"
    attn_chunk: int = 1024                  # q-chunk for blockwise attention when seq is long
    attn_direct_max: int = 2048             # use direct attention for seq <= this
    loss_chunk: int = 2048                  # token chunk for vocab-sharded chunked xent
    tie_embeddings: bool = True
    remat: bool = True                      # activation checkpointing per block group
    use_kernels: bool = False               # route hot ops through Pallas kernels (TPU)
    scan_layers: bool = True                # stack layout groups with jax.lax.scan

    # ------------------------------------------------------------------
    def __post_init__(self):
        hd = self.head_dim or (self.d_model // max(self.n_heads, 1))
        object.__setattr__(self, "head_dim", hd)
        if self.dt_rank is None:
            object.__setattr__(self, "dt_rank", max(1, math.ceil(self.d_model / 16)))
        if self.d_expert is None:
            object.__setattr__(self, "d_expert", self.d_ff)
        body = self.n_layers - self.first_k_dense
        if self.layout and body % len(self.layout) != 0:
            raise ValueError(
                f"{self.name}: n_layers-first_k_dense={body} not divisible by "
                f"layout length {len(self.layout)}")
        if any(m == "swa" for m, _ in self.layout) and self.window is None:
            raise ValueError(f"{self.name}: swa mixer requires window")
        if any(f == "moe" for _, f in self.layout) and self.n_experts <= 0:
            raise ValueError(f"{self.name}: moe layout requires n_experts > 0")

    # ------------------------------------------------------------------
    @property
    def n_groups(self) -> int:
        return (self.n_layers - self.first_k_dense) // len(self.layout)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_enc_dec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def has_cross(self) -> bool:
        return any(m in ("xattn", "attn_x") for m, _ in self.layout)

    @property
    def cross_len(self) -> int:
        """Length of the cross-attended embedding sequence."""
        return self.enc_seq if self.is_enc_dec else self.n_patches

    @property
    def attn_free(self) -> bool:
        return all(m == "mamba" for m, _ in self.layout) and self.first_k_dense == 0

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a multiple of 256 so the vocab dim
        shards evenly on any mesh (Megatron-style vocab padding); pad
        logits are masked out in the loss and at decode."""
        return -(-self.vocab_size // 256) * 256

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can serve very long contexts (long_500k):
        attention-free (SSM), sliding-window, or hybrid stacks whose full-
        attention layers are a small minority (Jamba 1:7 — decode cost is
        dominated by the recurrent mixers and the few KV caches fit when
        seq-sharded).  ``xattn`` attends to a fixed-length embedding
        sequence; ``attn_x`` contains full causal self attention."""
        def is_full_attn(m):
            return (m in ("attn", "attn_x")) and self.window is None

        full = sum(is_full_attn(m) for m, _ in self.layout)
        mamba = sum(m == "mamba" for m, _ in self.layout)
        if full == 0 and self.first_k_dense == 0:
            return True
        n_full = full * max(self.n_groups, 1) + self.first_k_dense
        return mamba > 0 and n_full / max(self.n_layers, 1) <= 0.25

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count of the constructed model (see model.init)."""
        from . import model as _model  # lazy; avoids cycle at import time
        import jax

        shapes = jax.eval_shape(lambda: _model.init(jax.random.PRNGKey(0), self))
        return sum(int(math.prod(x.shape)) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        from . import model as _model
        import jax
        import numpy as np

        shapes = jax.eval_shape(lambda: _model.init(jax.random.PRNGKey(0), self))
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            n = int(np.prod(leaf.shape))
            keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            if "routed" in keys and self.n_experts > 0:
                n = n * self.top_k // self.n_experts
            total += n
        return total
