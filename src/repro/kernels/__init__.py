from . import ops, ref
from .similarity import similarity_kernel
from .masked_agg import masked_agg_kernel
from .robust_agg import robust_agg_kernel
from .flash_attention import flash_attention_kernel
from .mamba_scan import mamba_scan_kernel
