"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
swept against in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def similarity_ref(z, g):
    z = z.astype(jnp.float32)
    g = g.astype(jnp.float32)
    return jnp.stack([jnp.sum(z * g, -1), jnp.sum(z * z, -1),
                      jnp.sum(g * g, -1)], axis=-1)


def masked_agg_ref(u, mask):
    """Eq. 6 oracle: mean of the mask-selected rows (same clamp as the
    kernel: an empty mask yields the zero update, not NaN)."""
    m = mask.astype(jnp.float32)
    u = u.astype(jnp.float32)
    return (u * m[:, None]).sum(0) / jnp.maximum(m.sum(), 1.0)


def dequant_int8_ref(q, scale, qblock: int):
    """Per-block symmetric int8 dequantization oracle.

    ``q``: (..., d) int8 payload; ``scale``: (..., nb) f32 per-block
    scales with nb = ceil(d / qblock).  The last axis is zero-padded to
    nb·qblock, scaled blockwise (q · scale, exact fp32 products), and
    sliced back to d.  This is the ONE decode definition: the int8
    codec's ``decode`` (fl/compression.py), the dense fallback rules,
    and the fused dequantize-and-fold kernel's ground truth
    (tests/test_compression.py) all route through it."""
    d = q.shape[-1]
    nb = scale.shape[-1]
    pad = nb * qblock - d
    qf = q.astype(jnp.float32)
    if pad:
        qf = jnp.pad(qf, [(0, 0)] * (qf.ndim - 1) + [(0, pad)])
    qf = qf.reshape(qf.shape[:-1] + (nb, qblock))
    out = (qf * scale[..., None].astype(jnp.float32))
    return out.reshape(out.shape[:-2] + (nb * qblock,))[..., :d]


def dequant_fold_ref(q, scale, w, acc, qblock: int):
    """Oracle for the dequantize-and-fold kernel:
    ``acc + Σ_i w_i · dequant(q_i, scale_i)`` over one client block."""
    dec = dequant_int8_ref(q, scale, qblock)
    w = w.astype(jnp.float32)
    return acc.astype(jnp.float32) + jnp.sum(dec * w[:, None], axis=0)


def median_ref(u):
    return jnp.median(u.astype(jnp.float32), axis=0)


def trimmed_ref(u, f: int):
    """Mean of the N-2f coordinates closest to the median (threshold
    formulation, matching the kernel's tie behaviour)."""
    u = u.astype(jnp.float32)
    n = u.shape[0]
    med = jnp.median(u, axis=0)
    d = jnp.abs(u - med[None])
    keep_n = max(n - 2 * f, 1)
    thresh = jnp.sort(d, axis=0)[keep_n - 1]
    w = (d <= thresh[None]).astype(jnp.float32)
    return (u * w).sum(0) / jnp.maximum(w.sum(0), 1.0)


def flash_attention_ref(q, k, v, window=None, softcap=None):
    """q: (B,H,Sq,dh), k/v: (B,K,Sk,dh) causal GQA attention, fp32 softmax."""
    B, H, Sq, dh = q.shape
    K, Sk = k.shape[1], k.shape[2]
    g = H // K
    qf = q.reshape(B, K, g, Sq, dh).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(jnp.float32))
    s = s / jnp.sqrt(dh)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, dh).astype(q.dtype)


def mamba_scan_ref(da, dbx, c):
    """Sequential reference: h_t = da_t h_{t-1} + dbx_t, y_t = <h_t, c_t>."""
    B, S, di, n = da.shape

    def step(h, xs):
        da_t, dbx_t, c_t = xs
        h = da_t * h + dbx_t
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    h0 = jnp.zeros((B, di, n), jnp.float32)
    _, y = jax.lax.scan(step, h0,
                        (da.swapaxes(0, 1).astype(jnp.float32),
                         dbx.swapaxes(0, 1).astype(jnp.float32),
                         c.swapaxes(0, 1).astype(jnp.float32)))
    return y.swapaxes(0, 1)
