"""Coordinate-wise robust aggregation kernel (Median / trimmed-mean).

The server-side baselines (Median [9], Bulyan's trimmed mean [12]) reduce
a stacked update matrix U (N clients, D) per coordinate.  This kernel
tiles D into VMEM blocks and sorts along the (small, compile-time) client
axis with an odd-even transposition network — pure min/max vector ops,
MXU-free and TPU-friendly — emitting both the median and the
mean-of-(N-2f)-closest-to-median in one pass.

Grid: (D/chunk,).  Block: (N, chunk) in VMEM: for N<=64, chunk=2048 fp32
this is 512 KB — well inside the ~16 MB VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 2048


def _oddeven_sort(u):
    """Sort rows of u (N, chunk) along axis 0 with an odd-even network."""
    n = u.shape[0]
    for it in range(n):
        start = it % 2
        for i in range(start, n - 1, 2):
            a, b = u[i], u[i + 1]
            lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
            u = u.at[i].set(lo).at[i + 1].set(hi)
    return u


def _kernel(u_ref, med_ref, trim_ref, *, f: int):
    u = u_ref[...].astype(jnp.float32)
    n = u.shape[0]
    s = _oddeven_sort(u)
    if n % 2:
        med = s[n // 2]
    else:
        med = 0.5 * (s[n // 2 - 1] + s[n // 2])
    med_ref[0, :] = med
    # Bulyan-style: mean of the N-2f values closest to the median.
    keep_n = max(n - 2 * f, 1)
    d = jnp.abs(s - med[None, :])
    ds = _oddeven_sort(d)            # sorted distances per coordinate
    thresh = ds[keep_n - 1]          # keep distances <= this
    w = (jnp.abs(u - med[None, :]) <= thresh[None, :]).astype(jnp.float32)
    # ties can admit >keep_n entries; normalize by actual count
    trim_ref[0, :] = jnp.sum(u * w, axis=0) / jnp.maximum(w.sum(0), 1.0)


def robust_agg_kernel(u, f: int = 0, *, chunk: int = DEFAULT_CHUNK,
                      interpret: bool = False):
    """u: (N, D) -> (median (D,), trimmed (D,)) fp32."""
    n, d = u.shape
    chunk = min(chunk, d)
    pad = (-d) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad)))
    d_p = u.shape[1]
    med, trim = pl.pallas_call(
        functools.partial(_kernel, f=f),
        grid=(d_p // chunk,),
        in_specs=[pl.BlockSpec((n, chunk), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, chunk), lambda i: (0, i)),
                   pl.BlockSpec((1, chunk), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, d_p), jnp.float32),
                   jax.ShapeDtypeStruct((1, d_p), jnp.float32)],
        interpret=interpret,
    )(u)
    return med[0, :d], trim[0, :d]
