"""Chunked selective-scan (Mamba-1 SSM) kernel.

TPU adaptation of the CUDA selective-scan: instead of a warp-level scan,
we exploit the *sequential* trailing grid dimension — the SSM state h
(d_inner-block, d_state) persists in VMEM scratch across sequence chunks,
and each chunk runs an in-register recurrence.  The channel dim is tiled
so each (chunk, d_block, d_state) working set fits VMEM.

Grid: (B, d_inner/bd, S/bs) — trailing = sequence (carried).
    h_t = dA_t * h_{t-1} + dBx_t ;   y_t = <h_t, C_t> + handled outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(da_ref, dbx_ref, c_ref, y_ref, h_ref, *, bs: int):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    da = da_ref[0].astype(jnp.float32)       # (bs, bd, n)
    dbx = dbx_ref[0].astype(jnp.float32)     # (bs, bd, n)
    c = c_ref[0].astype(jnp.float32)         # (bs, n)

    def step(t, h):
        h = da[t] * h + dbx[t]               # (bd, n)
        y = jnp.sum(h * c[t][None, :], axis=1)   # (bd,)
        # all-slice index: interpret mode's store-discharge rejects mixed
        # int/slice indices on some JAX versions
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y[None, None, :])
        return h

    h = jax.lax.fori_loop(0, bs, step, h_ref[...])
    h_ref[...] = h


def mamba_scan_kernel(da, dbx, c, *, bs: int = 128, bd: int = 512,
                      interpret: bool = False):
    """da, dbx: (B, S, di, n); c: (B, S, n) -> y: (B, S, di) fp32."""
    B, S, di, n = da.shape
    bs = min(bs, S)
    bd = min(bd, di)
    assert S % bs == 0 and di % bd == 0, (S, bs, di, bd)
    grid = (B, di // bd, S // bs)
    y = pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd, n), lambda b, j, s: (b, s, j, 0)),
            pl.BlockSpec((1, bs, bd, n), lambda b, j, s: (b, s, j, 0)),
            pl.BlockSpec((1, bs, n), lambda b, j, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd), lambda b, j, s: (b, s, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(da, dbx, c)
    return y
