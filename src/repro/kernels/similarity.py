"""Fused per-client similarity statistics kernel (DiverseFL Step 4).

Computes, for every client row j of the stacked update matrix Z and
guiding matrix G, the three reductions the C1/C2 criteria need —
(z·g, ‖z‖², ‖g‖²) — in a single pass over HBM.  The XLA baseline issues
three separate reductions (three reads of each operand); this kernel
reads each operand once, accumulating fp32 partials in a VMEM-resident
(1, 8) output block (padded to the fp32 sublane tile).

Grid: (N clients, D/chunk); the chunk axis is the trailing (sequential)
TPU grid dimension, so the output block persists in VMEM across chunk
iterations and is written back to HBM once per client.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

STATS_PAD = 8           # fp32 sublane tile; slots 0..2 used

DEFAULT_CHUNK = 16 * 1024


def _kernel(z_ref, g_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    z = z_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    dot = jnp.sum(z * g)
    zz = jnp.sum(z * z)
    gg = jnp.sum(g * g)
    out_ref[0, 0] += dot
    out_ref[0, 1] += zz
    out_ref[0, 2] += gg


def similarity_kernel(z, g, *, chunk: int = DEFAULT_CHUNK,
                      interpret: bool = False):
    """z, g: (N, D) -> (N, 3) fp32 [dot, ||z||^2, ||g||^2] per client."""
    n, d = z.shape
    chunk = min(chunk, d)
    pad = (-d) % chunk
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)))
        g = jnp.pad(g, ((0, 0), (0, pad)))
    d_p = z.shape[1]
    grid = (n, d_p // chunk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
                  pl.BlockSpec((1, chunk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, STATS_PAD), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, STATS_PAD), jnp.float32),
        interpret=interpret,
    )(z, g)
    return out[:, :3]
