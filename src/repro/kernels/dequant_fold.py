"""Fused dequantize-and-fold kernel — the int8 streaming aggregation pass.

The streaming AggState fold (fl/streaming.py) accumulates
``acc + Σ_i w_i·u_i`` one client block at a time.  With int8-compressed
update streams (fl/compression.py) the block arrives as an int8 payload
``q`` (1 byte/param) plus per-block f32 scales — dequantizing it to a
dense f32 block before the masked-agg kernel would cost an extra HBM
round-trip of 4·n·D bytes, exactly the traffic compression exists to
remove.  This kernel fuses the dequantization into the weighted-mean
fold: each (n, chunk) int8 tile streams through VMEM **once**, is scaled
in-register by its (n, chunk/qblock) scale tile, weighted, reduced over
clients, and added to the carried (1, chunk) accumulator tile — so the
aggregation pass reads 1 byte per update element instead of 4, and
decompression costs zero extra HBM passes over U.

Grid: (D/chunk,) with ``chunk`` a qblock multiple.  Blocks: weights
(n, 1) pinned; q (n, chunk) int8; scales (n, chunk/qblock) f32; the
accumulator (1, chunk) tile rides along and its buffer is donated via
``input_output_aliases`` — the same streaming-update contract as
``masked_agg.masked_agg_update_kernel``, which remains the fold kernel
for dense-payload codecs (its in-kernel f32 cast is bf16's whole
dequantization).

Numerics: the kernel computes ``(q·scale)·w`` with the identical
products and the identical axis-0 reduction as the reference
``kernels/ref.dequant_fold_ref``, so on exact-data cases (0/1 weights,
products representable) the two agree bitwise; in general the guarantee
is the usual block-fold fp tolerance (DESIGN.md §10).  Scale padding is
zeros, so padded columns contribute exact ±0.0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .masked_agg import DEFAULT_CHUNK


def dequant_fold_update_kernel(q, scale, w, acc, *, qblock: int,
                               chunk: int = DEFAULT_CHUNK,
                               interpret: bool = False):
    """Streaming int8 accumulate: ``acc + Σ_i w_i · (q_i ⊙ scale_i)``.

    q: (n, D) int8 payload; scale: (n, nb) f32 per-block scales with
    nb = ceil(D / qblock); w: (n,) raw per-client weights (mask already
    folded in, NO 1/|kept| normalization — that happens once at
    ``finalize``); acc: (D,) the carried AggState partial sum.  The
    payload is padded to nb·qblock (the decoder's padding) and then to a
    chunk multiple with zero scales, so padding contributes exact 0.
    """
    n, d = q.shape
    nb = scale.shape[1]
    w = w.astype(jnp.float32).reshape(n, 1)
    scale = scale.astype(jnp.float32)
    acc2 = acc.astype(jnp.float32).reshape(1, d)
    # chunk must tile in whole quantization blocks
    chunk = max(qblock, (min(chunk, nb * qblock) // qblock) * qblock)
    d_p = -(-(nb * qblock) // chunk) * chunk
    if d_p != d:
        q = jnp.pad(q, ((0, 0), (0, d_p - d)))
        acc2 = jnp.pad(acc2, ((0, 0), (0, d_p - d)))
    nb_p = d_p // qblock
    if nb_p != nb:
        scale = jnp.pad(scale, ((0, 0), (0, nb_p - nb)))
    cb = chunk // qblock

    def _kernel(w_ref, q_ref, s_ref, acc_ref, out_ref):
        wt = w_ref[...]                             # (n, 1) weights
        qf = q_ref[...].astype(jnp.float32)         # (n, chunk) int8 tile
        s = s_ref[...]                              # (n, cb) block scales
        sc = jnp.broadcast_to(s[:, :, None],
                              (s.shape[0], cb, qblock)).reshape(qf.shape)
        out_ref[...] = acc_ref[...] + jnp.sum((qf * sc) * wt, axis=0,
                                              keepdims=True)

    out = pl.pallas_call(
        _kernel,
        grid=(d_p // chunk,),
        in_specs=[pl.BlockSpec((n, 1), lambda i: (0, 0)),
                  pl.BlockSpec((n, chunk), lambda i: (0, i)),
                  pl.BlockSpec((n, cb), lambda i: (0, i)),
                  pl.BlockSpec((1, chunk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, chunk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d_p), jnp.float32),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(w, q, scale, acc2)
    return out[0, :d]
