"""Fused masked-mean aggregation kernel (DiverseFL Step 5, Eq. 6).

Computes the mean of the surviving client updates — ``mean(U[mask])`` —
in a single pass over HBM.  The XLA baseline materializes the mask
broadcast (``U * mask[:, None]``) and reduces it in a separate pass from
the similarity statistics; this kernel folds the mask *and* the
1/|kept| normalization into a per-client weight vector that stays
in-register (VMEM) while each (N, chunk) tile of ``U`` streams through
once.

Composed with kernels/similarity.py (via ops.diversefl_step45), the
whole DiverseFL Step 4+5 is two HBM passes over U and one over G:

    pass 1: similarity kernel  reads U, G   -> (dot, ‖z‖², ‖g‖²)/client
    (VPU)   diversefl_mask     on (N,) scalars, no HBM traffic
    pass 2: this kernel        reads U      -> masked mean (D,)

versus the unfused baseline's five operand passes (three reductions
over U/G for the stats, then select + mean over U again).

Grid: (D/chunk,).  Blocks: weights (N, 1) pinned to block (0, 0) every
iteration; U (N, chunk); output (1, chunk).  For N<=64, chunk=16384
fp32 the U tile is 4 MB — inside the ~16 MB VMEM budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 16 * 1024


def _kernel(w_ref, u_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)        # (N, 1) mask/denom weights
    u = u_ref[...].astype(jnp.float32)        # (N, chunk)
    out_ref[...] = jnp.sum(u * w, axis=0, keepdims=True)


def _update_kernel(w_ref, u_ref, acc_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)        # (n, 1) per-client weights
    u = u_ref[...].astype(jnp.float32)        # (n, chunk)
    acc = acc_ref[...].astype(jnp.float32)    # (1, chunk) carried partial
    out_ref[...] = acc + jnp.sum(u * w, axis=0, keepdims=True)


def masked_agg_update_kernel(u, w, acc, *, chunk: int = DEFAULT_CHUNK,
                             interpret: bool = False):
    """Streaming accumulate: ``acc + sum_i w_i * u_i`` over one client block.

    u: (n, D) update block; w: (n,) raw per-client weights (mask already
    folded in, NO 1/|kept| normalization — that happens once at
    ``finalize``); acc: (D,) the carried AggState partial sum.  One HBM
    pass over the block: each (n, chunk) tile of ``u`` streams through
    VMEM alongside the matching (1, chunk) tile of ``acc`` while the
    weight vector stays pinned.  ``input_output_aliases`` donates the
    accumulator's buffer, so sweeping a federation chunk-by-chunk updates
    one (D,) state in place instead of allocating a fresh partial per
    block — the kernel twin of fl/streaming.py's ``update_block``.
    """
    n, d = u.shape
    w = w.astype(jnp.float32).reshape(n, 1)
    acc2 = acc.astype(jnp.float32).reshape(1, d)
    chunk = min(chunk, d)
    pad = (-d) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad)))
        acc2 = jnp.pad(acc2, ((0, 0), (0, pad)))
    d_p = u.shape[1]
    out = pl.pallas_call(
        _update_kernel,
        grid=(d_p // chunk,),
        in_specs=[pl.BlockSpec((n, 1), lambda i: (0, 0)),
                  pl.BlockSpec((n, chunk), lambda i: (0, i)),
                  pl.BlockSpec((1, chunk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, chunk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d_p), jnp.float32),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(w, u, acc2)
    return out[0, :d]


def masked_agg_kernel(u, mask, *, chunk: int = DEFAULT_CHUNK,
                      interpret: bool = False):
    """u: (N, D); mask: (N,) bool/float -> (D,) fp32 masked mean (Eq. 6)."""
    n, d = u.shape
    m = mask.astype(jnp.float32)
    w = (m / jnp.maximum(m.sum(), 1.0)).reshape(n, 1)
    chunk = min(chunk, d)
    pad = (-d) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad)))
    d_p = u.shape[1]
    out = pl.pallas_call(
        _kernel,
        grid=(d_p // chunk,),
        in_specs=[pl.BlockSpec((n, 1), lambda i: (0, 0)),
                  pl.BlockSpec((n, chunk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, chunk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d_p), jnp.float32),
        interpret=interpret,
    )(w, u)
    return out[0, :d]
