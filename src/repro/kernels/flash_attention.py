"""Flash attention (causal, GQA, optional sliding window) for TPU.

Online-softmax tiling: grid (B, H, Sq/bq, Sk/bk) with the key axis as the
trailing (sequential) TPU grid dimension; running (m, l, acc) live in
VMEM scratch across key iterations.  Fully-masked key blocks — beyond the
causal frontier or outside the sliding window — are skipped with
``pl.when`` so compute is O(S·window) for SWA layers.

Block sizes default to MXU-aligned 128x128 q/k tiles with the full head
dim resident (head_dim <= 256 for all assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, scale: float, window, softcap, n_k: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = kj * bk
    # causal: need k_start <= q_end;  window: need k_end > q_start - window
    run = (k_start <= q_start + bq - 1)
    if window is not None:
        run &= (k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = (q @ k.T) * scale                        # (bq, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_cur = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_cur

    @pl.when(kj == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, window=None, softcap=None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q: (B, H, Sq, dh), k/v: (B, K, Sk, dh) — causal GQA flash attention.

    Returns (B, H, Sq, dh) in q.dtype."""
    B, H, Sq, dh = q.shape
    K, Sk = k.shape[1], k.shape[2]
    g = H // K
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded keys sit at positions >= Sk and are masked by causality
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_q, n_k = q.shape[2] // bq, k.shape[2] // bk
    grid = (B, H, n_q, n_k)
    kern = functools.partial(
        _kernel, bq=bq, bk=bk, scale=1.0 / (dh ** 0.5),
        window=window, softcap=softcap, n_k=n_k)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, q.shape[2], dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]
