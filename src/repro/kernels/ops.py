"""Jit'd public wrappers for the Pallas kernels.

On non-TPU backends (this CPU container) the kernels execute in pallas
interpret mode — same kernel body, Python/XLA interpretation — so every
call site works identically here and on real v5e hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dequant_fold as _dq
from . import flash_attention as _fa
from . import mamba_scan as _ms
from . import masked_agg as _ma
from . import robust_agg as _ra
from . import similarity as _sim
from .. import models
from ..core.diversefl import diversefl_mask


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def similarity_stats(z, g, chunk: int = _sim.DEFAULT_CHUNK):
    """(N, D) x (N, D) -> (N, 3) fp32 [dot, ||z||^2, ||g||^2]."""
    return _sim.similarity_kernel(z, g, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def masked_aggregate(u, mask, chunk: int = _ma.DEFAULT_CHUNK):
    """(N, D), (N,) -> (D,) masked mean (Eq. 6) in one HBM pass over u."""
    return _ma.masked_agg_kernel(u, mask, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def masked_agg_update(u, w, acc, chunk: int = _ma.DEFAULT_CHUNK):
    """Streaming accumulate: (n, D) block + (n,) weights + (D,) carried
    partial -> (D,) ``acc + sum_i w_i * u_i`` in one HBM pass over u.
    The Pallas leg of the streaming AggState ``update_block`` — the
    1/|kept| normalization happens once at ``finalize``, not here."""
    return _ma.masked_agg_update_kernel(u, w, acc, chunk=chunk,
                                        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("qblock", "chunk"))
def dequant_fold_update(q, scale, w, acc, qblock: int,
                        chunk: int = _ma.DEFAULT_CHUNK):
    """Streaming int8 accumulate: (n, D) int8 payload + (n, ceil(D/qblock))
    f32 per-block scales + (n,) weights + (D,) carried partial ->
    ``acc + sum_i w_i * dequant(q_i)`` with the dequantization fused into
    the one HBM pass over q (1 byte/element instead of 4).  The int8 leg
    of the streaming AggState ``update_block`` (fl/streaming.py); dense-
    payload codecs keep using :func:`masked_agg_update`, whose in-kernel
    f32 cast is their whole dequantization."""
    return _dq.dequant_fold_update_kernel(q, scale, w, acc, qblock=qblock,
                                          chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("cfg", "chunk"))
def diversefl_step45(u, g, cfg, chunk: int = _sim.DEFAULT_CHUNK):
    """Fused DiverseFL Step 4+5: (N, D) updates + guides -> (delta (D,),
    keep mask (N,), (dot, ||z||^2, ||g||^2)).

    Two HBM passes over u (similarity stats, masked mean) and one over g
    — the criterion itself runs on (N,) scalars in registers.  ``cfg`` is
    a (hashable) DiverseFLConfig."""
    stats = _sim.similarity_kernel(u, g, chunk=chunk, interpret=_interpret())
    dot, zz, gg = stats[:, 0], stats[:, 1], stats[:, 2]
    mask = diversefl_mask(dot, zz, gg, cfg)
    delta = _ma.masked_agg_kernel(u, mask, chunk=chunk, interpret=_interpret())
    return delta, mask, (dot, zz, gg)


@functools.partial(jax.jit, static_argnames=("f", "chunk"))
def robust_aggregate(u, f: int = 0, chunk: int = _ra.DEFAULT_CHUNK):
    """(N, D) -> (median (D,), trimmed_mean (D,))."""
    return _ra.robust_agg_kernel(u, f, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "softcap", "bq", "bk"))
def flash_attention_bhsd(q, k, v, window=None, softcap=None,
                         bq: int = 128, bk: int = 128):
    """q: (B,H,Sq,dh), k/v: (B,K,Sk,dh) -> (B,H,Sq,dh)."""
    return _fa.flash_attention_kernel(q, k, v, window=window, softcap=softcap,
                                      bq=bq, bk=bk, interpret=_interpret())


def flash_attention(q, k, v, window=None, softcap=None):
    """Model-layout adapter: q (B,S,H,dh), k/v (B,S,K,dh) -> (B,S,H,dh)."""
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    o = flash_attention_bhsd(qt, kt, vt, window=window, softcap=softcap)
    return o.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("bs", "bd"))
def mamba_scan_raw(da, dbx, c, bs: int = 128, bd: int = 512):
    return _ms.mamba_scan_kernel(da, dbx, c, bs=bs, bd=bd,
                                 interpret=_interpret())


def mamba_scan(xc, p, cfg):
    """Model adapter: post-conv activations -> scan output (B,S,di) fp32."""
    from ..models.mamba import _ssm_coeffs
    da, dbx, cm = _ssm_coeffs(xc, p, cfg)
    S, di = da.shape[1], da.shape[2]
    bs = 128 if S % 128 == 0 else S
    bd = 512 if di % 512 == 0 else di
    return mamba_scan_raw(da, dbx, cm, bs=bs, bd=bd)
