"""Adam / AdamW over pytrees (used by centralized pre-training and the
non-FL example drivers; FL local steps use plain SGD per the paper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_init(params):
    z = lambda p: jnp.zeros_like(p, jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8,
              weight_decay: float = 0.0):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                     jnp.square(g.astype(jnp.float32)), state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m, v):
        step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype)
    return (jax.tree.map(upd, params, m, v),
            {"m": m, "v": v, "t": t})
