from .sgd import sgd_init, sgd_step, apply_update
from .adam import adam_init, adam_step
from .schedules import (constant_lr, inv_sqrt_lr, step_decay_lr,
                        warmup_then_step_lr)
