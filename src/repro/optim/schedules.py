"""Learning-rate schedules used across the paper's experiments."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr0):
    return lambda i: jnp.float32(lr0)


def inv_sqrt_lr(lr0):
    """mu^(i) = lr0 / sqrt(i)  (softmax-regression experiments, after [23])."""
    return lambda i: jnp.float32(lr0) / jnp.sqrt(jnp.maximum(i, 1).astype(jnp.float32))


def step_decay_lr(lr0, boundaries, factor):
    """Step decay: multiply by `factor` at each boundary round."""
    bs = jnp.asarray(boundaries)

    def f(i):
        k = (i >= bs).sum()
        return jnp.float32(lr0) * jnp.float32(factor) ** k
    return f


def warmup_then_step_lr(lr_start, lr_peak, warmup_rounds, boundaries, factor):
    """CIFAR recipe: linear warmup lr_start->lr_peak, then step decay."""
    bs = jnp.asarray(boundaries)

    def f(i):
        i = jnp.asarray(i, jnp.float32)
        warm = lr_start + (lr_peak - lr_start) * jnp.minimum(
            i / jnp.maximum(warmup_rounds, 1), 1.0)
        k = (i >= bs).sum()
        return warm * jnp.float32(factor) ** k
    return f
