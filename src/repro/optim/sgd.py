"""SGD (optionally with momentum and weight decay) over pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return ()
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def sgd_step(params, grads, state, lr, momentum: float = 0.0,
             weight_decay: float = 0.0):
    if weight_decay:
        grads = jax.tree.map(
            lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
    if momentum == 0.0:
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_params, ()
    new_state = jax.tree.map(
        lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, new_state)
    return new_params, new_state


def apply_update(params, update, scale=1.0):
    """theta <- theta - scale * update  (server-side Eq. 6 application)."""
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32)
                      - scale * u.astype(jnp.float32)).astype(p.dtype),
        params, update)
