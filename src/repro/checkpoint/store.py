"""Minimal, dependency-free pytree checkpointing (npz + structure file).

Layout: <dir>/step_<n>.npz with flattened leaves keyed "leaf_<i>" plus a
pickled treedef sidecar.  Good enough for the simulator and example
drivers; a production deployment would swap in Orbax with the same API.
"""
from __future__ import annotations

import os
import pickle
import re
from typing import Optional

import jax
import numpy as np


def save_checkpoint(ckpt_dir: str, step: int, pytree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = jax.tree.flatten(pytree)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    np.savez(path, **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})
    with open(path + ".treedef", "wb") as f:
        pickle.dump(treedef, f)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", fn))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None):
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    with open(path + ".treedef", "rb") as f:
        treedef = pickle.load(f)
    leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
    return jax.tree.unflatten(treedef, leaves), step
