"""Quickstart — the paper in two minutes.

23 clients with extreme non-IID shards, 5 Byzantine clients sign-flipping
their updates.  DiverseFL filters them with the per-client C1/C2 criteria
and matches OracleSGD; coordinate-median limps; undefended mean collapses
under a Gaussian attack.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.attacks import AttackConfig
from repro.data import FederatedData, make_mnist_like, partition_sorted_shards
from repro.fl import (FLConfig, Federation, available_aggregators,
                      run_federated_training)
from repro.fl.small_models import softmax_regression
from repro.optim import inv_sqrt_lr


def main():
    x, y = make_mnist_like(jax.random.PRNGKey(0), 4600)
    tx, ty = make_mnist_like(jax.random.PRNGKey(9), 1000)
    data = FederatedData.from_partitions(partition_sorted_shards(x, y, 23), 10)
    model = softmax_regression()

    print("registered aggregation rules:", ", ".join(available_aggregators()))
    print(f"{'aggregator':12s} {'attack':11s} {'acc':>6s} {'TPR':>5s} {'FPR':>5s}")
    for agg, attack in [("oracle", "sign_flip"), ("diversefl", "sign_flip"),
                        ("median", "sign_flip"), ("mean", "gaussian"),
                        ("diversefl", "gaussian"), ("diversefl", "label_flip")]:
        cfg = FLConfig(rounds=60, aggregator=agg,
                       attack=AttackConfig(kind=attack, sigma=1e4),
                       batch_size=50, eval_every=60)
        fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
        h = run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))
        tpr = f"{h['mask_tpr'][-1]:.2f}" if h["mask_tpr"] else "   -"
        fpr = f"{h['mask_fpr'][-1]:.2f}" if h["mask_fpr"] else "   -"
        print(f"{agg:12s} {attack:11s} {h['final_acc']:6.3f} {tpr:>5s} {fpr:>5s}")


if __name__ == "__main__":
    main()
