"""Serving example: prefill a prompt, then batched greedy decode against
the KV/SSM cache — the same serve_step the decode_32k / long_500k
dry-runs lower, here on a reduced config.

    PYTHONPATH=src python examples/serve_decode.py --arch jamba-v0.1-52b
"""
import argparse

import jax
import jax.numpy as jnp

from repro import configs, models


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b",
                    choices=configs.all_arch_ids())
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=True)
    params = models.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    cache_len = args.prompt_len + args.gen

    # prefill by decoding the prompt token-by-token (shape-stable cache);
    # a production server would run the batched prefill forward instead.
    decode = jax.jit(
        lambda p, t, c, i: models.decode_step(p, cfg, t, c, i),
        donate_argnums=(2,))
    cache = models.init_cache(cfg, args.batch, cache_len)
    tok = prompt[:, :1]
    out = [tok]
    for t in range(cache_len - 1):
        lg, cache = decode(params, tok, cache, jnp.int32(t))
        if t + 1 < args.prompt_len:
            tok = prompt[:, t + 1:t + 2]          # teacher-force the prompt
        else:
            tok = jnp.argmax(lg, -1).astype(jnp.int32)  # greedy
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    print(f"{args.arch} (reduced): generated {args.gen} tokens x "
          f"{args.batch} sequences")
    for b in range(args.batch):
        seq = " ".join(str(int(x)) for x in toks[b, args.prompt_len:])
        print(f"  seq{b}: {seq}")


if __name__ == "__main__":
    main()
