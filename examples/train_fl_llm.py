"""End-to-end driver: federated training of a ~100M-parameter LM with the
*sharded* DiverseFL round step (the same code path the 512-chip dry-run
lowers), on a host mesh of 8 simulated devices = 4 FL clients x 2-way
model parallelism.  One client is Byzantine (sign flip) — watch it get
filtered every round while the loss drops.

    PYTHONPATH=src python examples/train_fl_llm.py --steps 300   # full
    PYTHONPATH=src python examples/train_fl_llm.py --steps 20    # demo
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import models
from repro.checkpoint import save_checkpoint
from repro.core.diversefl import DiverseFLConfig
from repro.data import make_token_stream
from repro.launch.train import make_fl_round_step
from repro.models import ModelConfig
from repro.sharding import partition_pytree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=640)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="fl-llm-100m", n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv_heads=4, d_ff=4 * args.d_model, vocab_size=32_000,
        attn_direct_max=args.seq)
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    print(f"model: {cfg.param_count()/1e6:.1f}M params; mesh {dict(mesh.shape)}"
          f" -> 4 FL clients x 2-way tensor parallel")

    params = models.init(jax.random.PRNGKey(0), cfg)
    params = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), partition_pytree(params)))
    step = make_fl_round_step(cfg, mesh, DiverseFLConfig(), lr=3e-2)

    key = jax.random.PRNGKey(1)
    byz = jnp.array([0, 0, 1, 0], jnp.int32)      # client 2 sign-flips
    for i in range(1, args.steps + 1):
        key, k1, k2 = jax.random.split(key, 3)
        tokens = make_token_stream(k1, 8, args.seq, cfg.vocab_size)
        inputs = {
            "tokens": tokens,
            # enclave sample = subset of each client's own shard (Step 1)
            "guide_tokens": tokens.reshape(4, 2, -1)[:, :1],
            "byz_kind": byz,
            "rng": jnp.zeros((2,), jnp.uint32),
        }
        t0 = time.time()
        params, m = step(params, inputs)
        if i % 5 == 0 or i == 1:
            mask = "".join("B" if not bool(x) else "." for x in m["mask"])
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"kept={int(m['kept'])}/4 clients[{mask}] "
                  f"{time.time()-t0:.2f}s")
    if args.ckpt:
        save_checkpoint(args.ckpt, args.steps, params)
        print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()
