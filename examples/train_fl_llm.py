"""End-to-end driver: federated training of a ~100M-parameter LM through
the compiled round engine on a host mesh of 8 simulated devices = 4 FL
clients x 2-way tensor (model) parallelism.  One client is Byzantine
(sign flip) — watch its updates get filtered while accuracy climbs.

This is the engine path (fl/engine.RoundEngine): the SAME Steps 2-5
definition every simulator run, sweep and benchmark compiles, here with
the flattened update vector model-sharded over the mesh's ``model`` axis
(DESIGN.md §12) — params take the MODEL_AXIS partition table's placement
and each round's whole eval segment runs as one donated device program.
The bespoke per-step shard_map loop this file used to carry is gone;
``launch.train.make_fl_round_step`` remains the production-mesh lowering
reference (see launch/dryrun.py), not a driver.

    PYTHONPATH=src python examples/train_fl_llm.py --rounds 300   # full
    PYTHONPATH=src python examples/train_fl_llm.py --rounds 20    # demo
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core.attacks import AttackConfig
from repro.fl import FLConfig, RoundEngine, make_zoo_federation, zoo_model
from repro.launch.mesh import make_host_mesh
from repro.models import ModelConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", "--steps", dest="rounds", type=int,
                    default=20)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=640)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="fl-llm-100m", n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv_heads=4, d_ff=4 * args.d_model, vocab_size=32_000,
        attn_direct_max=args.seq)
    mesh = make_host_mesh(data=4, model=2)
    print(f"model: {cfg.param_count()/1e6:.1f}M params; mesh {dict(mesh.shape)}"
          f" -> 4 FL clients x 2-way tensor parallel")

    model = zoo_model(cfg, seq_len=args.seq)
    fl = FLConfig(
        n_clients=4, f=1, rounds=args.rounds, batch_size=2, l2=0.0,
        aggregator="diversefl", streaming=True,
        eval_every=min(args.eval_every, args.rounds),
        attack=AttackConfig(kind="sign_flip"))   # client set by byz_mask
    fed = make_zoo_federation(model, fl, per_client=8, n_test=32)

    engine = RoundEngine(model, fed, fl, mesh=mesh)
    t0 = time.time()
    params, _, metrics, eval_rounds = engine.run_training(
        model.init(jax.random.PRNGKey(fl.seed + 1)),
        jax.random.PRNGKey(fl.seed),
        jnp.full((fl.rounds,), args.lr, jnp.float32))
    for r, acc, tpr in zip(np.asarray(eval_rounds),
                           np.asarray(metrics["acc"]),
                           np.asarray(metrics.get("mask_tpr", eval_rounds))):
        print(f"round {int(r):4d} acc={float(acc):.4f} "
              f"byz-detect-tpr={float(tpr):.2f}")
    print(f"{fl.rounds} rounds in {time.time()-t0:.1f}s "
          f"({engine.model_shards}-way model parallel)")
    if args.ckpt:
        save_checkpoint(args.ckpt, args.rounds, engine.carry_params(params))
        print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()
