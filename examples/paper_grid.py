"""Reproduce a small Table-style results grid in ONE invocation.

The paper's tables sweep attack kind x aggregator x seed; the sweep
engine (fl/sweep.py) runs the whole grid batched — cells sharing a
trace (same attack kind + aggregator here) compile once and execute as
a single vmapped device program, seeds batched along the scenario axis,
with per-cell results bitwise-equal to running each cell alone.

    PYTHONPATH=src python examples/paper_grid.py
"""
import time

import jax
import numpy as np

from repro.core.attacks import AttackConfig
from repro.data import FederatedData, make_mnist_like, partition_sorted_shards
from repro.fl import (FLConfig, Federation, SweepSpec, group_cells,
                      run_federated_sweep, trace_counter)
from repro.fl.small_models import softmax_regression
from repro.optim import inv_sqrt_lr

ATTACKS = (AttackConfig(kind="gaussian", sigma=1e4),
           AttackConfig(kind="sign_flip"),
           AttackConfig(kind="label_flip"),
           AttackConfig(kind="backdoor", source_class=3, target_class=4))
AGGREGATORS = ("diversefl", "oracle", "mean", "fltrust")
SEEDS = (0, 1, 2)


def main():
    x, y = make_mnist_like(jax.random.PRNGKey(0), 4600)
    tx, ty = make_mnist_like(jax.random.PRNGKey(9), 1000)
    data = FederatedData.from_partitions(partition_sorted_shards(x, y, 23), 10)
    model = softmax_regression()

    base = FLConfig(rounds=60, batch_size=50, eval_every=60)
    spec = SweepSpec(base=base, seeds=SEEDS, aggregators=AGGREGATORS,
                     attacks=ATTACKS)
    cells = spec.cells()
    fed = Federation.create(model, data, tx, ty, base, jax.random.PRNGKey(2))

    with trace_counter() as tc:
        t0 = time.time()
        results = run_federated_sweep(model, fed, spec, inv_sqrt_lr(0.05))
        dt = time.time() - t0
    compiles = tc["training"]
    print(f"{len(cells)} runs in {dt:.1f}s "
          f"({len(cells) / dt:.2f} experiments/sec), "
          f"{compiles} compiles for {len(group_cells(cells))} "
          f"structural groups\n")

    print(f"final accuracy, mean ± spread over {len(SEEDS)} seeds "
          f"(60 rounds, 23 clients, f=5):")
    header = "attack      " + "".join(f"{a:>16s}" for a in AGGREGATORS)
    print(header)
    for ai, atk in enumerate(ATTACKS):
        row = f"{atk.kind:12s}"
        for gi in range(len(AGGREGATORS)):
            # cells() order: aggregator outermost, then attack, seeds inner
            accs = [results[(gi * len(ATTACKS) + ai) * len(SEEDS) + s]
                    ["final_acc"] for s in range(len(SEEDS))]
            row += f"{np.mean(accs):10.3f}±{np.std(accs):.3f}"
        print(row)


if __name__ == "__main__":
    main()
