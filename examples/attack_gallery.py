"""Attack gallery — reproduce Fig. 2's separation: plot (ASCII) the
C1 x C2 similarity product per client over training under each attack.
Benign clients hover near +1; Byzantine clients go negative or explode.

    PYTHONPATH=src python examples/attack_gallery.py
"""
import jax
import numpy as np

from repro.core.attacks import AttackConfig
from repro.data import FederatedData, make_mnist_like, partition_sorted_shards
from repro.fl import FLConfig, Federation, run_federated_training
from repro.fl.small_models import mlp3
from repro.optim import inv_sqrt_lr


def main():
    x, y = make_mnist_like(jax.random.PRNGKey(0), 4600)
    tx, ty = make_mnist_like(jax.random.PRNGKey(9), 500)
    data = FederatedData.from_partitions(partition_sorted_shards(x, y, 23), 10)
    model = mlp3()

    for attack in ("sign_flip", "label_flip", "same_value"):
        cfg = FLConfig(rounds=30, aggregator="diversefl",
                       attack=AttackConfig(kind=attack, sigma=1e4),
                       batch_size=50, eval_every=5, l2=0.0005)
        fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
        h = run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))
        byz = np.asarray(fed.byz_mask)
        c = np.stack(h["c1c2"])              # (evals, 23)
        print(f"\n=== attack: {attack} — C1xC2 per client "
              f"(last eval; B=Byzantine) ===")
        for j in range(23):
            tag = "B" if byz[j] else " "
            val = c[-1, j]
            bar = "#" * min(40, int(abs(val) * 20))
            side = "-" if val < 0 else "+"
            print(f"  client {j:2d}{tag} {val:+8.3f} {side}{bar}")


if __name__ == "__main__":
    main()
