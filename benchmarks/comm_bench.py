"""Compressed update-stream benchmark — uplink bytes, temps, throughput.

The paper's clients ship their full-precision update to the enclave
every round; ``FLConfig.compression`` replaces that uplink with the
codec registry (fl/compression.py): bf16 halves the wire, int8 with
per-block scales quarters it, and per-client error-feedback residuals
keep the quantization noise from accumulating.  This bench makes the
communication cost a *measured* number, for an N=256 federation on the
streaming diversefl fold (mlp3, D ≈ 34k, ``client_chunk=64``):

* **wire bytes** — per-client uplink bytes of each codec's encoded
  form (``fl.compression.wire_bytes``: the exact payload byte count
  via ``jax.eval_shape``, scales included) and the round totals the
  history records (``fl.metrics.comm_stats``);
* **working set** — peak XLA temp of each codec's AOT-compiled scan
  segment vs the 512 MB enclave envelope: the error-feedback residual
  and the dequantize-and-fold path must not blow the memory budget the
  streaming fold bought;
* **ingest throughput** — the server-side fold timed with
  pre-encoded inputs vs dense f32: the stage compression actually
  touches in a deployment (clients encode in parallel on their own
  hardware; the enclave pays the decode).  int8 folds *fewer* bytes
  than dense (q + scales ≈ D/4), so fused dequantization must not
  give that advantage back — this is the measured form of the
  dequantize-and-fold kernel's "zero extra HBM passes over U" claim;
* **end-to-end sim rounds/sec** — recorded per codec.  On a
  single-core CPU host this number also serializes every simulated
  client's *encoder* (and the error-feedback residual passes), which
  no deployment does — it is reported for tracking, not gated;
* **collective census** — ``launch.hlo`` parse of each compiled
  segment (counts + moved bytes), recorded so a future multi-host
  lowering shows the wire saving inside the HLO too.

Acceptance (CI ``comm-smoke``):

* int8 uplink reduction >= 3.5x over dense f32 (measured from the
  encoded payload, not the 4x dtype ratio: the per-block scales eat
  part of the win);
* every codec's segment compiles under the envelope and completes;
* int8 ingest fold rounds/sec >= 0.9x the dense fold (compression
  must cost bytes, not server throughput);
* ``compression="f32"`` final params are **bitwise** equal to the
  default uncompressed run — the lossless codec short-circuits the
  error-feedback machinery entirely.

  PYTHONPATH=src python -m benchmarks.comm_bench [--smoke]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

MEM_ENVELOPE_MB = 512.0
N_CLIENTS = 256
CHUNK = 64
DIM, HIDDEN, N_CLASSES, M, PER_CLIENT = 256, 128, 10, 5, 6
AGGREGATOR = "diversefl"
CODECS = ("f32", "bf16", "int8")


def _build(rounds: int, *, compression: str = "f32"):
    from repro.core.attacks import AttackConfig
    from repro.data import FederatedData, make_classification
    from repro.data.partition import partition_sorted_shards
    from repro.fl import FLConfig, Federation, RoundEngine
    from repro.fl.small_models import mlp3

    x, y = make_classification(jax.random.PRNGKey(0),
                               N_CLIENTS * PER_CLIENT, N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, N_CLASSES, DIM)
    model = mlp3(input_dim=DIM, n_classes=N_CLASSES, hidden=HIDDEN)
    cfg = FLConfig(n_clients=N_CLIENTS, f=N_CLIENTS // 5,
                   aggregator=AGGREGATOR,
                   attack=AttackConfig(kind="sign_flip"), batch_size=M,
                   eval_every=rounds, l2=0.0, client_chunk=CHUNK,
                   streaming=True, compression=compression)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    engine = RoundEngine(model, fed, cfg, eval_every=rounds,
                         client_chunk=CHUNK)
    params = model.init(jax.random.PRNGKey(1))
    return model, fed, cfg, engine, params


def _compile_segment(engine, params, rounds: int):
    """AOT-compile one scan segment (carry-shaped: lossy codecs thread
    the (params, residual) carry) — nothing executes."""
    _key, subs = engine._segment_keys(jax.random.PRNGKey(0), rounds)
    lrs = jnp.zeros((rounds,), jnp.float32)
    carry = engine.init_carry(params)
    return engine._segment.lower(carry, subs, lrs, False, None,
                                 engine.default_scenario).compile()


def _run_segment(engine, params, cfg, rounds: int):
    from repro.optim import inv_sqrt_lr
    sched = inv_sqrt_lr(0.05)
    lrs = [float(sched(r)) for r in range(1, rounds + 1)]
    carry, _key, _logs = engine.run_segment(
        params, jax.random.PRNGKey(cfg.seed), lrs)
    jax.block_until_ready(jax.tree.leaves(carry)[0])
    return engine.carry_params(carry)


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def _fold_section(d: int):
    """Server-ingest throughput: the streaming diversefl fold timed on
    pre-encoded (N, D) inputs vs dense f32.  The encode is *not* timed
    — in a deployment it runs client-side, in parallel; what the server
    round-rate pays is folding the wire format it receives."""
    from repro.fl.compression import get_codec
    from repro.fl.server import AggregationContext
    from repro.fl.streaming import get_streaming, stream_aggregate

    from .common import emit

    rng = np.random.default_rng(0)
    U = jnp.asarray(rng.normal(size=(N_CLIENTS, d)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(N_CLIENTS, d)).astype(np.float32))

    def time_fold(name):
        codec = None if name == "dense" else get_codec(name)
        rule = get_streaming(AGGREGATOR).bind(AggregationContext(codec=codec))
        enc = U if codec is None else jax.jit(codec.encode)(U)
        jax.block_until_ready(jax.tree.leaves(enc)[0])

        def block_fn(blk, valid):
            u_b, g_b = blk
            return u_b, {"guide": g_b}

        fold = jax.jit(lambda a: stream_aggregate(rule, block_fn, a,
                                                  CHUNK, d=d))
        out = fold((enc, G))                                  # warmup
        jax.block_until_ready(out[0])
        best = np.inf                    # best-of: dodge box contention
        for _ in range(7):
            t0 = time.time()
            out = fold((enc, G))
            jax.block_until_ready(out[0])
            best = min(best, time.time() - t0)
        return best

    out = {}
    t_dense = time_fold("dense")
    out["dense"] = {"ms_per_fold": round(t_dense * 1e3, 1),
                    "folds_per_sec": round(1.0 / t_dense, 2)}
    for name in ("bf16", "int8"):
        t = time_fold(name)
        out[name] = {"ms_per_fold": round(t * 1e3, 1),
                     "folds_per_sec": round(1.0 / t, 2),
                     "vs_dense": round(t_dense / t, 3)}
        emit(f"comm/fold_{name}_n{N_CLIENTS}", t * 1e6,
             f"vs_dense={t_dense / t:.2f}x")
    return out


def run(smoke: bool = False):
    from repro.fl.compression import get_codec, wire_bytes
    from repro.fl.metrics import comm_stats
    from repro.launch.hlo import collective_stats, total_collective_bytes

    from .common import emit, write_report

    rounds = 1 if smoke else 2
    results = []
    rps = {}
    under_envelope = completes = True
    d = None
    for name in CODECS:
        model, fed, cfg, engine, params = _build(rounds, compression=name)
        if d is None:
            d = sum(p.size for p in jax.tree.leaves(params))
        codec = get_codec(name)
        per_client = wire_bytes(codec, d)
        compiled = _compile_segment(engine, params, rounds)
        temp_mb = compiled.memory_analysis().temp_size_in_bytes / 1e6
        hlo = compiled.as_text()
        colls = {k: v["count"]
                 for k, v in collective_stats(hlo).items() if v["count"]}
        _run_segment(engine, params, cfg, rounds)            # warmup
        t0 = time.time()
        p_out = _run_segment(engine, params, cfg, rounds)
        dt = time.time() - t0
        rps[name] = rounds / dt
        finite = bool(np.isfinite(_flat(p_out)).all())
        under_envelope &= temp_mb <= MEM_ENVELOPE_MB
        completes &= finite
        stats = comm_stats(cfg, d)
        results.append({
            "codec": name, "model_params": int(d),
            "uplink_bytes_per_client": int(per_client),
            "uplink_bytes_per_round": stats["uplink_bytes_per_round"],
            "dense_uplink_bytes_per_round":
                stats["dense_uplink_bytes_per_round"],
            "uplink_reduction": round(stats["uplink_reduction"], 3),
            "xla_temp_mb": round(temp_mb, 1),
            "sec_per_round": round(dt / rounds, 3),
            "rounds_per_sec": round(rps[name], 2),
            "collective_ops": colls,
            "collective_moved_bytes": total_collective_bytes(hlo),
            "completed": finite,
        })
        emit(f"comm/{name}_n{N_CLIENTS}", dt / rounds * 1e6,
             f"uplink={per_client}B|reduction="
             f"{stats['uplink_reduction']:.2f}x|xla_temp={temp_mb:.0f}MB")

    # f32 passthrough vs the default uncompressed run: bitwise params
    model, fed, cfg, engine, params = _build(rounds, compression="f32")
    p_f32 = _run_segment(engine, params, cfg, rounds)
    # default (field untouched) IS the uncompressed path
    model, fed, cfg_u, eng_u, params_u = _build(rounds)
    p_def = _run_segment(eng_u, params_u, cfg_u, rounds)
    f32_bitwise = bool(np.array_equal(_flat(p_f32), _flat(p_def)))

    int8_red = next(r["uplink_reduction"] for r in results
                    if r["codec"] == "int8")
    sim_ratio = rps["int8"] / rps["f32"]
    fold = _fold_section(d)
    emit(f"comm/int8_vs_f32_n{N_CLIENTS}", 0.0,
         f"sim_rps_ratio={sim_ratio:.2f}x|fold_vs_dense="
         f"{fold['int8']['vs_dense']:.2f}x|f32_bitwise={f32_bitwise}")

    acceptance = {
        "int8_uplink_reduction_ge_3_5x": int8_red >= 3.5,
        "all_codecs_under_envelope": bool(under_envelope),
        "all_codecs_complete": bool(completes),
        "int8_ingest_fold_ge_0_9x_dense": fold["int8"]["vs_dense"] >= 0.9,
        "f32_bitwise_vs_uncompressed": f32_bitwise,
    }
    return write_report("comm", smoke=smoke, acceptance=acceptance,
                        aggregator=AGGREGATOR, envelope_mb=MEM_ENVELOPE_MB,
                        n_clients=N_CLIENTS, client_chunk=CHUNK,
                        rounds=rounds, codecs=results,
                        ingest_fold=fold,
                        sim_rounds_per_sec={k: round(v, 3)
                                            for k, v in rps.items()},
                        sim_int8_vs_f32=round(sim_ratio, 3))


def main():
    from .common import smoke_main
    smoke_main(run)


if __name__ == "__main__":
    main()
