"""Analytic FLOP/byte models per (arch x shape) — the MODEL_FLOPS side of
the roofline table (6·N·D dense / 6·N_active·D MoE + attention terms).

XLA's HLO cost_analysis counts each while-loop (scan) body ONCE, so the
reported HLO FLOPs undercount scanned-layer models by ~n_groups; the
analytic model is the denominator-of-record for the usefulness ratio and
the compute roofline term (documented in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import functools

from repro import configs
from repro.launch.shapes import SHAPES


@functools.lru_cache(maxsize=None)
def _active_params(arch_id: str) -> int:
    return configs.get(arch_id).active_param_count()


def _attn_layers(cfg) -> int:
    per_group = sum(1 for m, _ in cfg.layout if m in ("attn", "swa",
                                                      "attn_x"))
    return cfg.first_k_dense + per_group * cfg.n_groups


def _cross_layers(cfg) -> int:
    per_group = sum(1 for m, _ in cfg.layout if m in ("xattn", "attn_x"))
    return per_group * cfg.n_groups


def _mamba_layers(cfg) -> int:
    per_group = sum(1 for m, _ in cfg.layout if m == "mamba")
    return per_group * cfg.n_groups


def _ctx(cfg, S):
    """Mean causal context length (window-limited for SWA)."""
    if cfg.window is not None:
        return min(cfg.window, S)
    return S / 2


def model_flops(arch_id: str, shape_name: str, n_clients: int = 16,
                guide_batch: int = 1) -> float:
    """Whole-step FLOPs across all chips (divide by chip count per chip)."""
    cfg = configs.get(arch_id)
    shape = SHAPES[shape_name]
    B, S = shape.batch, shape.seq
    Na = _active_params(arch_id)
    H, dh = max(cfg.n_heads, 1), cfg.head_dim or 1

    def fwd_flops(tokens, seq_ctx):
        f = 2.0 * Na * tokens
        f += 4.0 * _attn_layers(cfg) * tokens * seq_ctx * H * dh
        f += 4.0 * _cross_layers(cfg) * tokens * cfg.cross_len * H * dh
        f += 9.0 * _mamba_layers(cfg) * tokens * cfg.d_inner * cfg.ssm_state
        return f

    if shape.kind == "train":
        tokens = B * S
        guide_tokens = n_clients * guide_batch * S
        return 3.0 * (fwd_flops(tokens, _ctx(cfg, S)) +
                      fwd_flops(guide_tokens, _ctx(cfg, S)))
    if shape.kind == "prefill":
        return fwd_flops(B * S, _ctx(cfg, S))
    # decode: one token against an S-long cache
    f = 2.0 * Na * B
    ctx = min(cfg.window or S, S)
    f += 4.0 * _attn_layers(cfg) * B * ctx * H * dh
    f += 4.0 * _cross_layers(cfg) * B * cfg.cross_len * H * dh
    f += 9.0 * _mamba_layers(cfg) * B * cfg.d_inner * cfg.ssm_state
    return f


def decode_min_bytes(arch_id: str, shape_name: str) -> float:
    """Memory-bound floor for decode: params(active) + cache read once."""
    cfg = configs.get(arch_id)
    shape = SHAPES[shape_name]
    B, S = shape.batch, shape.seq
    pbytes = 2.0 * _active_params(arch_id)
    ctx = min(cfg.window or S, S)
    kv = (4.0 * _attn_layers(cfg) * B * ctx * cfg.n_kv_heads *
          (cfg.head_dim or 0))
    ssm = 4.0 * _mamba_layers(cfg) * B * cfg.d_inner * cfg.ssm_state * 4
    return pbytes + kv + ssm
