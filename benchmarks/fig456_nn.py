"""Figs. 4-6 — neural-network training (non-convex) under non-targeted
attacks: 3-NN on MNIST-like and the Appendix-C small CNN on CIFAR-like
synthetic data.  Paper claim: DiverseFL ~= OracleSGD; cross-client
defences degrade under heterogeneity."""
from __future__ import annotations

import jax

from repro.core.attacks import AttackConfig
from repro.data import FederatedData, make_cifar_like, partition_sorted_shards
from repro.fl.small_models import mlp3, small_cnn

from .common import emit, mnist_like_federation, timed_fl_run

SCHEMES = ("oracle", "diversefl", "median", "fltrust")
ATTACKS = ("gaussian", "sign_flip", "label_flip")


def run(rounds: int = 40):
    # --- Fig. 4: MNIST-like / 3-NN ---
    data, tx, ty = mnist_like_federation()
    model = mlp3()
    for attack in ATTACKS:
        acfg = AttackConfig(kind=attack, sigma=10.0)
        for scheme in SCHEMES:
            hist, _, us = timed_fl_run(model, data, tx, ty, scheme, acfg,
                                       rounds=rounds, l2=0.0005)
            emit(f"fig4/mnist_3nn/{attack}/{scheme}", us,
                 f"{hist['final_acc']:.4f}")

    # --- Fig. 5 analogue: CIFAR-like / small CNN (Appendix C model) ---
    x, y = make_cifar_like(jax.random.PRNGKey(0), 2300)
    txc, tyc = make_cifar_like(jax.random.PRNGKey(9), 500)
    datac = FederatedData.from_partitions(
        partition_sorted_shards(x, y, 23), 10)
    cnn = small_cnn()
    for attack in ("sign_flip",):
        acfg = AttackConfig(kind=attack, sigma=10.0)
        for scheme in ("oracle", "diversefl", "median"):
            hist, _, us = timed_fl_run(cnn, datac, txc, tyc, scheme, acfg,
                                       rounds=25, lr0=0.08, l2=0.0005)
            emit(f"fig5/cifar_cnn/{attack}/{scheme}", us,
                 f"{hist['final_acc']:.4f}")
