"""Streaming-aggregation benchmark — dense (N, D) vs AggState folding.

Measures, for N ∈ {256, 1024, 4096} clients on an MLP whose flattened
update is D ≈ 34k params, the peak round working set and the wall time
of:

* **dense** — the engine's default path: client updates are chunked but
  the stacked (N, D) update matrix plus its (N, D) guide twin
  materialize for the aggregator registry;
* **streaming** — ``FLConfig.streaming=True``: updates and guides are
  folded block-by-block into an O(D) AggState (fl/streaming.py); only
  O(chunk·D) is ever alive.

The peak working set is **measured, not estimated**: each variant's
scan segment is AOT-lowered and compiled, and XLA's
``memory_analysis().temp_size_in_bytes`` reports the compiled
executable's peak temporary-buffer allocation — the number that
actually decides whether a round fits an enclave-sized memory budget.
(Compiling allocates nothing, so the over-budget dense 4096-client
segment can be *measured* and then skipped rather than estimated away;
the analytic U+G accounting is reported alongside for interpretation.)
The N=4096 dense segment exceeds the 512 MB envelope and is skipped as
over-budget (recorded, not silently dropped); the streaming segment
must compile inside the envelope *and* complete a round.

``--smoke`` (CI): one round per segment and a non-zero exit when the
acceptance criteria fail — streaming == dense bitwise at N=256, the
dense 4096-client path measured over the envelope, and the 4096-client
streaming round compiled inside it and completing.

  PYTHONPATH=src python -m benchmarks.streaming_bench [--smoke]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

MEM_ENVELOPE_MB = 512.0
SIZES = (256, 1024, 4096)
CHUNK = 64
DIM, HIDDEN, N_CLASSES, M, PER_CLIENT = 256, 128, 10, 5, 6
AGGREGATOR = "diversefl"


def _build(n_clients: int, rounds: int, *, streaming: bool,
           use_kernel_agg: bool = False):
    from repro.core.attacks import AttackConfig
    from repro.data import FederatedData, make_classification
    from repro.data.partition import partition_sorted_shards
    from repro.fl import FLConfig, Federation, RoundEngine
    from repro.fl.small_models import mlp3

    x, y = make_classification(jax.random.PRNGKey(0),
                               n_clients * PER_CLIENT, N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, n_clients), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, N_CLASSES, DIM)
    model = mlp3(input_dim=DIM, n_classes=N_CLASSES, hidden=HIDDEN)
    cfg = FLConfig(n_clients=n_clients, f=n_clients // 5,
                   aggregator=AGGREGATOR,
                   attack=AttackConfig(kind="sign_flip"), batch_size=M,
                   eval_every=rounds, l2=0.0, client_chunk=CHUNK,
                   streaming=streaming, use_kernel_agg=use_kernel_agg)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    engine = RoundEngine(model, fed, cfg, eval_every=rounds,
                         client_chunk=CHUNK)
    params = model.init(jax.random.PRNGKey(1))
    return model, fed, cfg, engine, params


def _segment_temp_mb(engine, params, rounds: int) -> float:
    """Peak XLA temporary-buffer bytes of the compiled scan segment —
    the measured round working set (compile only; nothing executes)."""
    _key, subs = engine._segment_keys(jax.random.PRNGKey(0), rounds)
    lrs = jnp.zeros((rounds,), jnp.float32)
    lowered = engine._segment.lower(params, subs, lrs, False, None,
                                    engine.default_scenario)
    stats = lowered.compile().memory_analysis()
    return stats.temp_size_in_bytes / 1e6


def _run_segment(engine, params, cfg, rounds: int):
    from repro.optim import inv_sqrt_lr
    sched = inv_sqrt_lr(0.05)
    lrs = [float(sched(r)) for r in range(1, rounds + 1)]
    params, _key, logs = engine.run_segment(
        params, jax.random.PRNGKey(cfg.seed), lrs)
    jax.block_until_ready(jax.tree.leaves(params)[0])
    return params, logs


def _n_params() -> int:
    # mlp3(DIM, HIDDEN, N_CLASSES): two dense layers with bias
    return DIM * HIDDEN + HIDDEN + HIDDEN * N_CLASSES + N_CLASSES


def dense_agg_mb(n_clients: int) -> float:
    """Analytic floor for the dense path: U (N, D) + guides G (N, D)."""
    return 2 * n_clients * _n_params() * 4 / 1e6


def streaming_agg_mb() -> float:
    """Analytic: one (chunk, D) update block + guide block + O(D) state."""
    return (2 * CHUNK + 1) * _n_params() * 4 / 1e6


def run(smoke: bool = False):
    from repro.fl.chunking import resolve_shards
    from repro.sharding import data_shard_count

    from .common import emit, write_report
    rounds = 1 if smoke else 2
    d = _n_params()
    results = []
    bitwise_256 = None
    temps = {}
    for n in SIZES:
        # the fold partition actually configured for this row: chunk,
        # requested shard count (None = auto from the mesh), and the
        # count resolve_shards settles on for the padded block count
        k_blocks = -(-n // CHUNK)
        entry = {"n_clients": n, "client_chunk": CHUNK, "model_params": d,
                 "blocks": k_blocks, "stream_shards_requested": None,
                 "stream_shards_resolved": resolve_shards(
                     data_shard_count(), k_blocks),
                 "rounds": rounds,
                 "dense_UG_floor_mb": round(dense_agg_mb(n), 1),
                 "streaming_blocks_mb": round(streaming_agg_mb(), 1)}
        # --- streaming: measure compiled temps, then run ---
        model, fed, cfg, engine, params = _build(n, rounds, streaming=True)
        t_strm = _segment_temp_mb(engine, params, rounds)
        temps[("strm", n)] = t_strm
        entry["streaming_xla_temp_mb"] = round(t_strm, 1)
        p_strm, logs = _run_segment(engine, params, cfg, rounds)  # warmup
        t0 = time.time()
        p_strm, logs = _run_segment(engine, params, cfg, rounds)
        dt_s = time.time() - t0
        entry["streaming_sec_per_round"] = round(dt_s / rounds, 3)
        finite = all(bool(np.isfinite(np.asarray(p)).all())
                     for p in jax.tree.leaves(p_strm))
        entry["streaming_completed"] = \
            finite and logs["mask"].shape == (cfg.n_selected,)
        emit(f"streaming/strm_n{n}", dt_s / rounds * 1e6,
             f"xla_temp={t_strm:.0f}MB")
        # --- dense: measure compiled temps; run only inside the envelope ---
        model, fed, cfg_d, eng_d, params_d = _build(n, rounds,
                                                    streaming=False)
        t_dense = _segment_temp_mb(eng_d, params_d, rounds)
        temps[("dense", n)] = t_dense
        entry["dense_xla_temp_mb"] = round(t_dense, 1)
        if t_dense > MEM_ENVELOPE_MB:
            entry["dense"] = (f"skipped: measured {t_dense:.0f}MB XLA temp "
                              f"> {MEM_ENVELOPE_MB:.0f}MB envelope")
            emit(f"streaming/dense_n{n}", 0.0,
                 f"skipped|xla_temp={t_dense:.0f}MB")
        else:
            _run_segment(eng_d, params_d, cfg_d, rounds)         # warmup
            t0 = time.time()
            p_dense, _ = _run_segment(eng_d, params_d, cfg_d, rounds)
            dt_d = time.time() - t0
            entry["dense_sec_per_round"] = round(dt_d / rounds, 3)
            emit(f"streaming/dense_n{n}", dt_d / rounds * 1e6,
                 f"xla_temp={t_dense:.0f}MB|strm/dense={dt_s / dt_d:.2f}x")
            if n == 256:
                a = np.concatenate([np.asarray(v).ravel()
                                    for v in jax.tree.leaves(p_strm)])
                b = np.concatenate([np.asarray(v).ravel()
                                    for v in jax.tree.leaves(p_dense)])
                bitwise_256 = bool(np.array_equal(a, b))
                entry["streaming_matches_dense_bitwise"] = bitwise_256
        results.append(entry)

    n_big = SIZES[-1]
    big = next(e for e in results if e["n_clients"] == n_big)
    emit(f"streaming/mem_n{n_big}", 0.0,
         f"strm_temp={temps[('strm', n_big)]:.0f}MB_vs_dense_temp="
         f"{temps[('dense', n_big)]:.0f}MB")
    acceptance = {
        "streaming_matches_dense_n256": bool(bitwise_256),
        "dense_4096_skipped_over_envelope":
            temps[("dense", n_big)] > MEM_ENVELOPE_MB,
        "streaming_4096_under_envelope":
            temps[("strm", n_big)] <= MEM_ENVELOPE_MB,
        "streaming_4096_completes": bool(big["streaming_completed"]),
    }
    return write_report("streaming", smoke=smoke, acceptance=acceptance,
                        aggregator=AGGREGATOR, envelope_mb=MEM_ENVELOPE_MB,
                        sizes=results)


def main():
    from .common import smoke_main
    smoke_main(run)


if __name__ == "__main__":
    main()
