"""§Roofline — renders the roofline table from the dry-run JSON:
three terms (compute / memory / collective) per (arch x shape x mesh),
dominant bottleneck, MODEL_FLOPS vs HLO_FLOPs usefulness ratio, and a
one-line lever per row.

Emits CSV rows name,us_per_call,derived where us_per_call is the dominant
roofline term (microseconds) and derived = "dominant|ratio"."""
from __future__ import annotations

import json
import os

from repro.launch import hlo as hlo_lib

from .analytic import model_flops
from .common import emit

LEVERS = {
    ("compute",): "increase per-chip arithmetic intensity (larger local batch"
                  " or fewer remat recomputes)",
    ("memory",): "cut HBM traffic: bf16 intermediates, fuse reductions,"
                 " smaller attention chunks, avoid involuntary resharding",
    ("collective",): "reshard to cut all-gathers (2D expert sharding,"
                     " reduce-scatter aggregation, overlap with compute)",
}


def run(path: str = "results/dryrun_baseline_merged.json"):
    if not os.path.exists(path):
        print(f"# roofline: {path} missing — run "
              f"`python -m repro.launch.dryrun --out {path}` first")
        return
    with open(path) as f:
        records = json.load(f)
    for r in records:
        if r.get("status") != "ok":
            if r.get("status") == "skip":
                emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                     "skip:sub-quadratic-required")
            continue
        chips = 512 if r["mesh"] == "2x16x16" else 256
        nc = 32 if r["mesh"] == "2x16x16" else 16
        rf = r["roofline"]
        mf = model_flops(r["arch"], r["shape"], n_clients=nc) / chips
        t_model = mf / hlo_lib.PEAK_FLOPS_BF16
        t_dom = max(rf["t_compute"], rf["t_memory"], rf["t_collective"],
                    t_model)
        ratio = mf / max(rf["flops"], 1.0)
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             t_dom * 1e6,
             f"dom={rf['dominant']}|t_c={rf['t_compute']:.2e}"
             f"|t_m={rf['t_memory']:.2e}|t_x={rf['t_collective']:.2e}"
             f"|t_model={t_model:.2e}|model/hlo_flops={ratio:.1f}")
