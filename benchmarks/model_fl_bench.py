"""Tensor-sharded federated rounds — the model zoo on the compiled engine.

What this bench measures: a federated round over a *real* transformer
(the zoo adapter, fl/zoo.py) with the flat model dim D sharded over the
mesh's ``model`` axis (DESIGN.md §12) — the configuration that decides
whether 100M+-parameter federations fit the paper's 512 MB enclave
envelope at all.

Sections:

* **envelope** — AOT-compile the engine's multi-round segment for a
  ≥100M-param LM (full mode; a zoo smoke config under ``--smoke``) on
  client x model host meshes and record
  ``memory_analysis().temp_size_in_bytes`` against the 512 MB envelope.
  The blocked (ms, L) update layout (sharding.flatten_updates_sharded)
  keeps per-shard temps at O(D/ms): the measured matrix shows temps
  scaling *down* with the model axis — the unsharded build pins ~5 full
  D-sized f32 temps regardless of mesh.
* **throughput** — run the compiled segment (not just compile it) on
  the sharded mesh and unsharded, and record rounds/sec both ways.  On
  a single host the 8 forced devices share cores, so the ratio is a
  plumbing check, not a speedup claim — the acceptance is that the
  sharded program *completes* with finite metrics.
* **model-axis=1 gate** — the same training run on a ``model=1`` mesh
  must reproduce the meshless engine history **bitwise** (every eval
  metric): the degrade-gracefully contract that keeps every pre-zoo
  config byte-identical.

Acceptance (smoke-gated in CI):

* sharded segment compiles AND runs with temps <= the envelope;
* model-axis=1 history bitwise == meshless history;
* full mode additionally records the >=100M-param segment inside the
  envelope on the client x model mesh (the PR's headline number).

  PYTHONPATH=src python -m benchmarks.model_fl_bench [--smoke]
"""
from __future__ import annotations

import os
import sys
import time

# The client x model mesh wants 8 host devices; forcing them is only
# possible before jax initializes.  Under ``benchmarks.run`` jax may
# already be imported — the bench then degrades gracefully (mesh
# sections are skipped, the meshless gate still runs).
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attacks import AttackConfig
from repro.fl import FLConfig, RoundEngine, make_zoo_federation, zoo_model
from repro.launch.mesh import make_host_mesh
from repro.models import ModelConfig
from repro.sharding import use_mesh

from .common import emit, smoke_main, write_report

MEM_ENVELOPE_MB = 512.0
AGGREGATOR = "diversefl"
SEQ = 32

# 13 x (640, 8H/4KV, 2560ff) + 32k vocab = 100,369,280 params — the
# smallest config of this family over the 10^8 floor the acceptance
# criterion names.
FULL_MODEL = ModelConfig(name="fl-llm-100m", n_layers=13, d_model=640,
                         n_heads=8, n_kv_heads=4, d_ff=2560,
                         vocab_size=32_000, attn_direct_max=SEQ)
# tiny gate model: layout checks are scale-free
TINY_MODEL = ModelConfig(name="fl-llm-tiny", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab_size=256, attn_direct_max=16)


def _cfg(n_clients: int, rounds: int) -> FLConfig:
    return FLConfig(
        n_clients=n_clients, f=1 if n_clients > 1 else 0, rounds=rounds,
        batch_size=2, l2=0.0, aggregator=AGGREGATOR, streaming=True,
        client_chunk=1, eval_every=rounds, compression="f32",
        attack=AttackConfig(kind="sign_flip" if n_clients > 1 else "none"))


def _engine(model, cfg, mesh=None):
    fed = make_zoo_federation(model, cfg, per_client=4, n_test=16)
    return RoundEngine(model, fed, cfg, mesh=mesh)


def _segment_temp_mb(eng, params, rounds: int) -> float:
    """Peak XLA temp of the AOT-compiled multi-round segment.  The
    lowering MUST happen under the engine's mesh — outside ``use_mesh``
    every model-axis constraint silently no-ops and the number measures
    the unsharded program."""
    carry = eng._prepare_carry(params)
    _k, subs = eng._segment_keys(jax.random.PRNGKey(0), rounds)
    lrs = jnp.zeros((rounds,), jnp.float32)
    with use_mesh(eng.mesh):
        comp = eng._segment.lower(carry, subs, lrs, False, None,
                                  eng.default_scenario).compile()
    return comp.memory_analysis().temp_size_in_bytes / 1e6


def _timed_run(eng, params, rounds: int):
    """(metrics dict of np arrays, rounds/sec) for a short training."""
    lrs = jnp.full((rounds,), 3e-2, jnp.float32)
    t0 = time.time()
    _p, _k, metrics, _er = eng.run_training(
        params, jax.random.PRNGKey(0), lrs)
    metrics = {k: np.asarray(v) for k, v in metrics.items()}
    jax.block_until_ready(metrics)
    return metrics, rounds / (time.time() - t0)


def _history_bitwise(a: dict, b: dict) -> bool:
    return (set(a) == set(b)
            and all(np.array_equal(a[k], b[k], equal_nan=True) for k in a))


def run(smoke: bool = False):
    rounds = 2
    model_cfg = TINY_MODEL if smoke else FULL_MODEL
    model = zoo_model(model_cfg, seq_len=SEQ if not smoke else 16)
    params = model.init(jax.random.PRNGKey(1))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    have_mesh = jax.device_count() >= 8

    # ---- envelope: temps vs (data, model) mesh shape ----------------
    temps = {}
    if have_mesh:
        for data, mdl in ((2, 4), (1, 8)):
            eng = _engine(model, _cfg(n_clients=max(data, 2), rounds=rounds),
                          mesh=make_host_mesh(data=data, model=mdl))
            temps[f"{data}x{mdl}"] = _segment_temp_mb(eng, params, rounds)
            emit(f"model_fl/temp_mb_{data}x{mdl}",
                 0.0, f"{temps[f'{data}x{mdl}']:.1f}")
    eng_flat = _engine(model, _cfg(n_clients=2, rounds=rounds))
    temps["unsharded"] = _segment_temp_mb(eng_flat, params, rounds)
    emit("model_fl/temp_mb_unsharded", 0.0, f"{temps['unsharded']:.1f}")

    # ---- throughput: the sharded segment must RUN, not just compile -
    sharded_rps = None
    sharded_ok = True
    if have_mesh:
        eng_s = _engine(model, _cfg(n_clients=2, rounds=rounds),
                        mesh=make_host_mesh(data=2, model=4))
        m_s, sharded_rps = _timed_run(eng_s, params, rounds)
        sharded_ok = all(np.isfinite(v).all() for v in m_s.values())
        emit("model_fl/sharded_rounds_per_sec", 1e6 / max(sharded_rps, 1e-9),
             f"{sharded_rps:.4f}")
    m_f, flat_rps = _timed_run(eng_flat, params, rounds)
    flat_ok = all(np.isfinite(v).all() for v in m_f.values())
    emit("model_fl/unsharded_rounds_per_sec", 1e6 / max(flat_rps, 1e-9),
         f"{flat_rps:.4f}")

    # ---- model-axis=1 bitwise gate (scale-free: tiny model) ---------
    gate_model = zoo_model(TINY_MODEL, seq_len=16)
    gate_params = gate_model.init(jax.random.PRNGKey(1))
    gcfg = _cfg(n_clients=4, rounds=4)
    hist_meshless, _ = _timed_run(_engine(gate_model, gcfg),
                                  gate_params, gcfg.rounds)
    bitwise = True
    if have_mesh:
        hist_m1, _ = _timed_run(
            _engine(gate_model, gcfg, mesh=make_host_mesh(data=4, model=1)),
            gate_params, gcfg.rounds)
        bitwise = _history_bitwise(hist_meshless, hist_m1)
    emit("model_fl/model_axis1_bitwise", 0.0, bitwise)

    sharded_temp = temps.get("2x4")
    acceptance = {
        "model_axis1_bitwise_vs_meshless": bitwise,
        "sharded_run_completes_finite": sharded_ok,
        "unsharded_run_completes_finite": flat_ok,
        "sharded_under_envelope":
            sharded_temp is None or sharded_temp <= MEM_ENVELOPE_MB,
    }
    if not smoke:
        acceptance["ge_100m_params"] = n_params >= 100_000_000
        acceptance["envelope_100m_client_x_model"] = (
            sharded_temp is not None and sharded_temp <= MEM_ENVELOPE_MB
            and n_params >= 100_000_000 and sharded_ok)

    return write_report(
        "model_fl", smoke=smoke, acceptance=acceptance,
        config={"model": model_cfg.name, "n_params": int(n_params),
                "rounds": rounds, "aggregator": AGGREGATOR,
                "envelope_mb": MEM_ENVELOPE_MB,
                "devices": jax.device_count(),
                "mesh_sections": have_mesh},
        temps_mb={k: round(v, 1) for k, v in temps.items()},
        rounds_per_sec={"sharded_2x4": sharded_rps,
                        "unsharded": flat_rps})


if __name__ == "__main__":
    smoke_main(run)
