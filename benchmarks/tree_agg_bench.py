"""Hierarchical two-tier aggregation benchmark — million-client rounds.

The ceiling this bench measures: how many clients one aggregation round
fits when the fold is hierarchical (fl/streaming.py ``pods=``,
DESIGN.md §9).  Client updates and DiverseFL guides are generated *on
the fly inside the fold's block_fn* — exactly the engine's staging
(updates are computed per block inside the scan, never stacked) — so
the only O(N) arrays alive are the int32 client index vector and the
per-client criterion logs; the working set is O(chunk·D) per pod lane
plus the O(pods·D) cross-pod partial AggStates.

For N = 10^6 clients (``--smoke``: 10^5) at D = 256 and chunk = 500,
each pod count P ∈ {1, 2, 4, 8}:

* **measured** peak XLA temp of the AOT-compiled fold
  (``memory_analysis().temp_size_in_bytes``) vs the 512 MB enclave
  envelope — the same measurement streaming_bench uses;
* wall time per aggregation round, rounds/sec, clients/sec.

Acceptance (smoke-gated in CI):

* the N-client round compiles **under the envelope and completes** at
  every pod count;
* ``pods=1`` is **bitwise** equal (delta + per-client C1/C2 logs) to
  the single-tier fold — at the fold level here, and at the training
  level (``FLConfig.pods=1`` vs ``pods=None`` final params);
* ``pods=2``: per-client logs bitwise vs ``pods=1``, delta to fp
  tolerance (tier-2 merge reassociates — documented, not hidden);
* with exact integer updates and 0/1 weights the two-tier fold is
  bitwise across every pod count (association, never math);
* on ≥2 host devices, executing the P=2 fold under an active
  ``("pod", "data", "model")`` mesh reproduces the meshless P=2 fold
  (logs bitwise; delta to tight fp tolerance) — placement cannot
  change the association.

  PYTHONPATH=src python -m benchmarks.tree_agg_bench [--smoke]
"""
from __future__ import annotations

import functools
import os
import sys
import time

# The mesh-placement check wants multiple host devices; forcing them is
# only possible before jax initializes.  Under ``benchmarks.run`` jax is
# already imported — the bench then degrades gracefully (the two-tier
# fold itself needs no mesh; only the placement check is skipped).
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

MEM_ENVELOPE_MB = 512.0
POD_COUNTS = (1, 2, 4, 8)
D = 256             # small model on purpose: the axis under test is N
CHUNK = 500         # k = N / 500 blocks — divisible by every pod count
N_FULL = 1_000_000
N_SMOKE = 100_000
BYZ_FRAC = 0.2
AGGREGATOR = "diversefl"


def _bound_rule():
    """The diversefl AggState monoid plus a generator block_fn: updates
    and guides are *computed from the client index inside the fold* —
    honest clients move along a common base direction, Byzantine ones
    sign-flip it — so no (N, D) array ever exists host- or device-side."""
    from repro.fl.server import AggregationContext
    from repro.fl.streaming import get_streaming

    base_key, u_key, g_key = jax.random.split(jax.random.PRNGKey(7), 3)
    rule = get_streaming(AGGREGATOR).bind(AggregationContext())

    def block_fn(blk, valid):
        (idx,) = blk
        base = jax.random.normal(base_key, (D,), jnp.float32)
        byz = idx % int(1 / BYZ_FRAC) == 0

        def row(i, b):
            nu = jax.random.normal(jax.random.fold_in(u_key, i), (D,))
            ng = jax.random.normal(jax.random.fold_in(g_key, i), (D,))
            sign = jnp.where(b, -1.0, 1.0)
            return sign * base + 0.3 * nu, base + 0.1 * ng

        U, G = jax.vmap(row)(idx, byz)
        return U, {"byz": byz, "guide": G}

    return rule, block_fn


def _make_fold(rule, block_fn):
    from repro.fl.streaming import stream_aggregate

    @functools.partial(jax.jit, static_argnames=("pods",))
    def fold(idx, pods):
        return stream_aggregate(rule, block_fn, (idx,), CHUNK, d=D,
                                pods=pods)
    return fold


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def _logs_bitwise(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def _training_pods1_bitwise() -> dict:
    """FLConfig.pods=1 routes the engine through the identical
    single-tier code path: final params must be bitwise equal to
    pods=None — the PR-4 one-dispatch fold."""
    from repro.core.attacks import AttackConfig
    from repro.data import (FederatedData, make_classification,
                            partition_sorted_shards)
    from repro.fl import (FLConfig, Federation, run_federated_training,
                          softmax_regression)
    from repro.optim import inv_sqrt_lr

    N, DIM, NC = 64, 8, 4
    x, y = make_classification(jax.random.PRNGKey(0), N * 8, NC, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N), NC)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, NC, DIM)
    model = softmax_regression(input_dim=DIM, n_classes=NC)

    def train(pods):
        cfg = FLConfig(n_clients=N, f=12, rounds=2, batch_size=2,
                       eval_every=2, l2=0.0, client_chunk=8, streaming=True,
                       aggregator=AGGREGATOR, pods=pods,
                       attack=AttackConfig(kind="sign_flip"))
        fed = Federation.create(model, data, tx, ty, cfg,
                                jax.random.PRNGKey(2))
        return run_federated_training(model, fed, cfg, inv_sqrt_lr(0.05))

    h_flat, h_p1, h_p2 = train(None), train(1), train(2)
    return {
        "training_pods1_bitwise_params":
            bool(np.array_equal(_flat(h_flat["params"]),
                                _flat(h_p1["params"]))),
        "training_pods2_masks_bitwise_params_close":
            h_flat["mask_tpr"] == h_p2["mask_tpr"]
            and h_flat["mask_fpr"] == h_p2["mask_fpr"]
            and bool(np.allclose(_flat(h_p2["params"]),
                                 _flat(h_flat["params"]),
                                 rtol=1e-5, atol=1e-6)),
    }


def _exact_data_bitwise_across_pods() -> bool:
    """Integer updates + 0/1 weights: every add exact, so the two-tier
    merge must reproduce the flat fold bit for bit at every P."""
    from repro.fl.server import AggregationContext
    from repro.fl.streaming import get_streaming, stream_aggregate

    rng = np.random.default_rng(3)
    n, d, chunk = 32, 11, 2
    U = jnp.asarray(rng.integers(-8, 8, size=(n, d)).astype(np.float32))
    byz = jnp.asarray(rng.random(n) < 0.3)
    rule = get_streaming("oracle").bind(AggregationContext(byz_mask=byz))

    def block_fn(blk, valid):
        u_blk, byz_b = blk
        return u_blk, {"byz": byz_b}

    ref, _, _ = stream_aggregate(rule, block_fn, (U, byz), chunk, d=d)
    return all(
        np.array_equal(np.asarray(stream_aggregate(
            rule, block_fn, (U, byz), chunk, d=d, pods=p)[0]),
            np.asarray(ref))
        for p in (2, 4, 8))


def _mesh_placement_check(fold, idx) -> bool | None:
    """P=2 fold under an active pod mesh == the meshless P=2 fold
    (logs bitwise, delta tight-close).  None = skipped (one device)."""
    if len(jax.devices()) < 2:
        return None
    from repro.launch.mesh import make_host_pod_mesh
    from repro.sharding import use_mesh

    d_ref, _, lg_ref = fold(idx, pods=2)
    with use_mesh(make_host_pod_mesh(pods=2, data=1, model=1)):
        d_mesh, _, lg_mesh = fold(idx, pods=2)
    return bool(_logs_bitwise(lg_ref, lg_mesh)
                and np.allclose(np.asarray(d_mesh), np.asarray(d_ref),
                                rtol=1e-6, atol=1e-8))


def run(smoke: bool = False):
    from .common import emit, write_report

    n = N_SMOKE if smoke else N_FULL
    k = n // CHUNK
    rule, block_fn = _bound_rule()
    fold = _make_fold(rule, block_fn)
    idx = jnp.arange(n, dtype=jnp.int32)

    results = []
    baseline = None                  # (delta, logs) at pods=1
    pods2_logs_bitwise = pods2_delta_close = None
    under_envelope = completes = True
    for p in POD_COUNTS:
        lowered = fold.lower(idx, pods=p)
        compiled = lowered.compile()
        temp_mb = compiled.memory_analysis().temp_size_in_bytes / 1e6
        delta, _, logs = compiled(idx)                    # warmup
        jax.block_until_ready(delta)
        t0 = time.time()
        delta, _, logs = compiled(idx)
        jax.block_until_ready(delta)
        dt = time.time() - t0
        ok = bool(np.isfinite(np.asarray(delta)).all())
        under_envelope &= temp_mb <= MEM_ENVELOPE_MB
        completes &= ok
        if p == 1:
            baseline = (np.asarray(delta), logs)
        elif p == 2:
            pods2_logs_bitwise = _logs_bitwise(logs, baseline[1])
            # tier-2 merge reassociates a ~N-term f32 accumulation; the
            # random-walk rounding gap grows ~sqrt(N)·eps (~1e-5 at 1e5
            # clients), so the tolerance is scale-aware, not fixed
            tol = 3e-5 * float(np.sqrt(n / 1e5))
            pods2_delta_close = bool(np.allclose(
                np.asarray(delta), baseline[0], rtol=1e-4, atol=tol))
        results.append({
            "pods": p, "n_clients": n, "model_params": D,
            "client_chunk": CHUNK, "blocks": k,
            "xla_temp_mb": round(temp_mb, 1),
            "sec_per_round": round(dt, 3),
            "rounds_per_sec": round(1.0 / dt, 3),
            "clients_per_sec": round(n / dt),
            "completed": ok,
        })
        emit(f"tree_agg/pods{p}_n{n}", dt * 1e6,
             f"xla_temp={temp_mb:.0f}MB|clients_per_s={n / dt:.2e}")

    # pods=1 vs the default (pods unset) single-tier fold: bitwise
    from repro.fl.streaming import stream_aggregate
    d_flat, _, lg_flat = jax.jit(
        lambda ix: stream_aggregate(rule, block_fn, (ix,), CHUNK, d=D))(idx)
    pods1_bitwise = bool(
        np.array_equal(np.asarray(d_flat), baseline[0])
        and _logs_bitwise(lg_flat, baseline[1]))

    mesh_ok = _mesh_placement_check(fold, idx)
    acceptance = {
        f"n{n}_under_{MEM_ENVELOPE_MB:.0f}mb_all_pod_counts":
            bool(under_envelope),
        f"n{n}_round_completes_all_pod_counts": bool(completes),
        "pods1_bitwise_vs_single_tier": pods1_bitwise,
        "pods2_logs_bitwise_vs_pods1": bool(pods2_logs_bitwise),
        "pods2_delta_close_vs_pods1": bool(pods2_delta_close),
        "exact_data_bitwise_across_pods":
            _exact_data_bitwise_across_pods(),
        **_training_pods1_bitwise(),
    }
    if mesh_ok is not None:     # one-device runs skip, recorded not gated
        acceptance["pods2_mesh_placement_matches_meshless"] = mesh_ok

    return write_report("tree_agg", smoke=smoke, acceptance=acceptance,
                        aggregator=AGGREGATOR, envelope_mb=MEM_ENVELOPE_MB,
                        n_clients=n, dim=D, client_chunk=CHUNK,
                        devices=len(jax.devices()),
                        pod_counts=results)


def main():
    from .common import smoke_main
    smoke_main(run)


if __name__ == "__main__":
    main()
