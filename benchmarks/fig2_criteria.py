"""Fig. 2 — separation of the C1 x C2 product between benign and Byzantine
clients across training rounds.  Derived metric: the margin between the
lowest benign C1xC2 and the highest Byzantine C1xC2 (paper: benign stay
positive ~1; Byzantine go negative almost exclusively)."""
from __future__ import annotations

import numpy as np

from repro.core.attacks import AttackConfig
from repro.fl.small_models import mlp3

from .common import emit, mnist_like_federation, timed_fl_run


def run(rounds: int = 40):
    data, tx, ty = mnist_like_federation()
    model = mlp3()
    hist, fed, us = timed_fl_run(model, data, tx, ty, "diversefl",
                                 AttackConfig(kind="label_flip"),
                                 rounds=rounds, l2=0.0005)
    byz = np.asarray(fed.byz_mask)
    c1c2 = np.stack(hist["c1c2"])            # (evals, N)
    benign_min = c1c2[:, ~byz].min()
    byz_max = c1c2[:, byz].max()
    emit("fig2/benign_c1c2_min", us, f"{benign_min:.3f}")
    emit("fig2/byzantine_c1c2_max", us, f"{byz_max:.3f}")
    emit("fig2/separated", us, int(benign_min > 0 > byz_max))
