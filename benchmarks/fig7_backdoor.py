"""Fig. 7 — targeted backdoor with x5 model-replacement scaling: main-task
vs backdoor accuracy.  Paper claim: FLTrust is breached (non-zero backdoor
accuracy) while DiverseFL keeps backdoor accuracy ~0 at OracleSGD-level
main accuracy."""
from __future__ import annotations

from repro.core.attacks import AttackConfig
from repro.fl.metrics import backdoor_accuracy, main_task_accuracy
from repro.fl.small_models import mlp3

from .common import emit, mnist_like_federation, timed_fl_run


def run(rounds: int = 40):
    data, tx, ty = mnist_like_federation()
    model = mlp3()
    acfg = AttackConfig(kind="backdoor", scale=5.0, source_class=3,
                        target_class=4)
    for scheme in ("oracle", "diversefl", "fltrust", "mean"):
        hist, fed, us = timed_fl_run(model, data, tx, ty, scheme, acfg,
                                     rounds=rounds, l2=0.0005)
        main = main_task_accuracy(model, hist["params"], tx, ty, acfg)
        bd = backdoor_accuracy(model, hist["params"], tx, ty, acfg)
        emit(f"fig7/main_acc/{scheme}", us, f"{main:.4f}")
        emit(f"fig7/backdoor_acc/{scheme}", us, f"{bd:.4f}")
