"""Fig. 8 — sample-poisoning mitigation: 8 of 23 clients share label-
flipped enclave samples; the pre-trained clean model (trained on
10%/5%/2% clean fractions) screens them.  Paper claim: even 2% clean data
suffices to detect all poisoned clients, restoring OracleSGD accuracy."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.attacks import AttackConfig
from repro.core.sample_filter import (FilterConfig, pretrain_clean_model,
                                      screen_clients)
from repro.data import make_mnist_like
from repro.fl.simulator import FLConfig, Federation
from repro.fl.small_models import softmax_regression

from .common import emit, mnist_like_federation


def run():
    data, tx, ty = mnist_like_federation()
    model = softmax_regression()
    n_total = data.n_clients * data.per_client
    for frac in (0.10, 0.05, 0.02):
        cfg = FLConfig(n_clients=data.n_clients, f=8,
                       aggregator="diversefl",
                       attack=AttackConfig(kind="label_flip"))
        fed = Federation.create(model, data, tx, ty, cfg,
                                jax.random.PRNGKey(2))
        byz_ids = [int(i) for i in np.where(np.asarray(fed.byz_mask))[0]]
        for cid in byz_ids:
            xx, yy = fed.enclave.unseal_samples(cid)
            fed.enclave.seal_samples(cid, xx, 9 - yy)

        n_clean = max(64, int(frac * n_total))
        clean_x, clean_y = make_mnist_like(jax.random.PRNGKey(77), n_clean)
        fcfg = FilterConfig(threshold=0.7)
        import time
        t0 = time.time()
        pre = pretrain_clean_model(model, clean_x, clean_y, fcfg,
                                   jax.random.PRNGKey(5))
        accepted, accs = screen_clients(model, pre, fed.enclave, fcfg)
        us = (time.time() - t0) * 1e6
        detected = sum(1 for c in byz_ids if c not in accepted)
        false_pos = sum(1 for c in range(data.n_clients)
                        if c not in byz_ids and c not in accepted)
        emit(f"fig8/clean_{int(frac*100)}pct/detected_of_8", us, detected)
        emit(f"fig8/clean_{int(frac*100)}pct/false_pos", us, false_pos)
