"""Tables II-IV — final accuracy for f=5 vs f=17 (of 23) Byzantine
clients across the four attacks.  Paper claim: DiverseFL ~= OracleSGD even
with ~75% Byzantine clients (per-client criteria need no majority)."""
from __future__ import annotations

from repro.core.attacks import AttackConfig
from repro.fl.small_models import mlp3

from .common import emit, mnist_like_federation, timed_fl_run

ATTACKS = ("sign_flip", "label_flip", "gaussian", "same_value")


def run(rounds: int = 40):
    data, tx, ty = mnist_like_federation()
    model = mlp3()
    for f in (5, 17):
        for attack in ATTACKS:
            acfg = AttackConfig(kind=attack, sigma=10.0)
            for scheme in ("oracle", "diversefl"):
                hist, _, us = timed_fl_run(model, data, tx, ty, scheme, acfg,
                                           rounds=rounds, f=f, l2=0.0005)
                emit(f"tab2-4/f{f}/{attack}/{scheme}", us,
                     f"{hist['final_acc']:.4f}")
