"""Render EXPERIMENTS.md §Dry-run/§Roofline markdown tables from the
dry-run JSONs.  Usage:
    PYTHONPATH=src python -m benchmarks.render_tables results/dryrun_baseline_merged.json
"""
from __future__ import annotations

import json
import sys

from repro.launch import hlo as hlo_lib

from .analytic import model_flops


def render(path: str, title: str = "Baseline") -> str:
    recs = json.load(open(path))
    out = [f"#### {title} ({path})", "",
           "| arch | shape | mesh | HLO GFLOP | GB acc | coll GB | "
           "t_comp(HLO) | t_comp(model) | t_mem | t_coll | dominant | "
           "model/HLO | fits16G |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs = sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9),
                                       r["mesh"]))
    for r in recs:
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"— | — | — | — | — | — | — | SKIP (full-attention; "
                       f"DESIGN.md §4) | — | — |")
            continue
        if r["status"] != "ok":
            continue
        chips = 512 if r["mesh"] == "2x16x16" else 256
        nc = 32 if r["mesh"] == "2x16x16" else 16
        rf = r["roofline"]
        mf = model_flops(r["arch"], r["shape"], n_clients=nc) / chips
        t_model = mf / hlo_lib.PEAK_FLOPS_BF16
        ratio = mf / max(rf["flops"], 1.0)
        mem = r.get("memory", {})
        per_dev = (mem.get("argument_size_in_bytes", 0) +
                   mem.get("temp_size_in_bytes", 0) +
                   mem.get("output_size_in_bytes", 0))
        fits = "yes" if per_dev <= 16 * 2 ** 30 else f"no ({per_dev/2**30:.0f}G)"
        dom = rf["dominant"]
        if t_model > max(rf["t_memory"], rf["t_collective"]):
            dom = "compute*"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{rf['flops']/1e9:.0f} | {rf['bytes']/1e9:.1f} | "
            f"{rf['collective_bytes']/1e9:.2f} | "
            f"{rf['t_compute']:.2e} | {t_model:.2e} | {rf['t_memory']:.2e} | "
            f"{rf['t_collective']:.2e} | {dom} | {ratio:.1f} | {fits} |")
    return "\n".join(out)


if __name__ == "__main__":
    for p in sys.argv[1:]:
        print(render(p, p))
        print()
