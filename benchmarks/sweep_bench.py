"""Batched experiment sweeps benchmark — experiments/sec and compiles,
measured (DESIGN.md §8).

The paper's tables are grids (attack kind x aggregator x seed); after
the one-dispatch engine each cell still paid its own trace/compile and
dispatched alone.  This bench runs a paper-style grid over the four
streaming-family aggregators and four attack kinds at N=256 twice:

* **sequential** — the status quo: one ``run_federated_training`` per
  cell, each building its own engine, so every cell compiles and
  dispatches alone;
* **batched** — ``run_federated_sweep``: cells partitioned into
  structural groups (here: attack x aggregator; seeds batch), each
  group one vmapped compile and one dispatch + final host sync.

Compiles are **counted, not asserted from the code**: every engine
program bumps ``repro.fl.engine.TRACE_COUNTS`` exactly once per trace,
so the bench snapshots the counters around each pass — the batched pass
must trace exactly once per structural group.  Per-cell histories and
final params of the two passes must agree **bitwise** (vmap batches the
numbers, it must not change them).  Acceptance (CI ``sweep-smoke``):
>= 3x experiments/sec batched over sequential, exactly one compile per
structural group, bitwise parity on every cell.

  PYTHONPATH=src python -m benchmarks.sweep_bench [--smoke]
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import numpy as np

N_CLIENTS = 256
DIM, N_CLASSES, PER_CLIENT = 8, 4, 8
AGGREGATORS = ("diversefl", "oracle", "mean", "fltrust")


def _attacks(smoke: bool):
    from repro.core.attacks import AttackConfig
    base = (AttackConfig(kind="gaussian", sigma=1e4),
            AttackConfig(kind="sign_flip"),
            AttackConfig(kind="label_flip"),
            AttackConfig(kind="backdoor", source_class=1, target_class=2))
    if not smoke:
        return base
    # smoke adds a magnitude axis — paper tables sweep attack strength,
    # and sigma/scale are scenario *data*: the extra cells join the
    # existing structural groups instead of adding compiles, which is
    # exactly the economics this bench exists to measure
    return base + (AttackConfig(kind="gaussian", sigma=1e2),
                   AttackConfig(kind="backdoor", source_class=1,
                                target_class=2, scale=2.0))


def _build(rounds: int, eval_every: int):
    from repro.data import FederatedData, make_classification
    from repro.data.partition import partition_sorted_shards
    from repro.fl import FLConfig, Federation
    from repro.fl.small_models import softmax_regression

    x, y = make_classification(jax.random.PRNGKey(0),
                               N_CLIENTS * PER_CLIENT, N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, N_CLASSES, DIM)
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    base = FLConfig(n_clients=N_CLIENTS, f=N_CLIENTS // 5, rounds=rounds,
                    eval_every=eval_every, batch_size=2, l2=0.0)
    fed = Federation.create(model, data, tx, ty, base, jax.random.PRNGKey(2))
    return model, fed, base


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def run(smoke: bool = False, seeds: Optional[int] = None):
    from repro.fl import (SweepSpec, group_cells, run_federated_sweep,
                          run_federated_training, trace_counter)
    from repro.optim import inv_sqrt_lr
    from .common import emit, write_report

    # smoke maximizes cells-per-group (the speedup is ~ group_size /
    # vmap-compile-overhead, measured ~1.45x, since the smoke runs are
    # compile-dominated); full mode favors longer runs over more seeds
    if seeds is None:
        seeds = 4 if smoke else 3
    rounds, eval_every = (2, 2) if smoke else (20, 10)
    model, fed, base = _build(rounds, eval_every)
    sched = inv_sqrt_lr(0.05)
    spec = SweepSpec(base=base, seeds=tuple(range(seeds)),
                     aggregators=AGGREGATORS, attacks=_attacks(smoke))
    cells = spec.cells()
    n_cells, n_groups = len(cells), len(group_cells(cells))

    # --- sequential: one engine + compile + dispatch chain per cell ---
    with trace_counter() as tc:
        t = time.time()
        seq = [run_federated_training(model, fed, c.cfg, sched)
               for c in cells]
        t_seq = time.time() - t
    seq_traces = tc.snapshot()

    # --- batched: one compile + one dispatch per structural group -----
    with trace_counter() as tc:
        t = time.time()
        bat = run_federated_sweep(model, fed, spec, sched)
        t_bat = time.time() - t
    bat_traces = tc.snapshot()

    eps_seq, eps_bat = n_cells / t_seq, n_cells / t_bat
    speedup = eps_bat / eps_seq
    bitwise = all(
        np.array_equal(_flat(b["params"]), _flat(s["params"]))
        and all(np.array_equal(np.asarray(b[k]), np.asarray(s[k]))
                for k in s if k != "params")
        for b, s in zip(bat, seq))

    emit(f"sweep/sequential_n{N_CLIENTS}", 1e6 * t_seq / n_cells,
         f"{eps_seq:.2f}eps|compiles={seq_traces['training']}")
    emit(f"sweep/batched_n{N_CLIENTS}", 1e6 * t_bat / n_cells,
         f"{eps_bat:.2f}eps|compiles={bat_traces['training']}"
         f"|speedup={speedup:.2f}x")

    acceptance = {
        "one_compile_per_structural_group":
            bat_traces["training"] == n_groups
            and bat_traces["segment"] == 0 and bat_traces["eval"] == 0,
        "batched_bitwise_equals_sequential": bool(bitwise),
        "speedup_ge_3x" if smoke else "speedup_ge_1x":
            speedup >= (3.0 if smoke else 1.0),
    }
    return write_report(
        "sweep", smoke=smoke, acceptance=acceptance,
        n_clients=N_CLIENTS, rounds=rounds, eval_every=eval_every,
        grid={"attacks": [(a.kind, a.sigma, a.scale)
                          for a in _attacks(smoke)],
              "aggregators": list(AGGREGATORS), "seeds": seeds,
              "cells": n_cells, "structural_groups": n_groups},
        sequential={"sec_total": round(t_seq, 3),
                    "experiments_per_sec": round(eps_seq, 3),
                    "traces": seq_traces},
        batched={"sec_total": round(t_bat, 3),
                 "experiments_per_sec": round(eps_bat, 3),
                 "traces": bat_traces},
        speedup=round(speedup, 2))


def main():
    from .common import smoke_main
    smoke_main(run)


if __name__ == "__main__":
    main()
