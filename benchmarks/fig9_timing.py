"""Fig. 9 — TEE scalability: measured guiding-update time per client
(enclave side) vs a modeled edge-client round time; derived = how many
clients one enclave supports without stalling (paper: 490 for softmax@1%,
~119-150 for VGG-11, dropping ~3-4x at 3% sampling).

We measure the *actual* guiding-update computation on this host (per
paper model), then apply core.tee.Enclave.max_clients with the paper's
edge/TEE speed ratio."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.diversefl import guiding_update
from repro.core.tee import Enclave
from repro.data import make_mnist_like, make_cifar_like
from repro.fl.small_models import mlp3, small_cnn, softmax_regression

from .common import emit

# paper's measured relative edge-client step times (compute+comm, RPi 3
# at 100 Mbps), normalized to the TEE guiding-update unit of each model.
EDGE_STEP_SECONDS = {"softmax_regression": 2.0, "mlp3": 2.5, "small_cnn": 8.0}


def _measure_guide_us(model, x, y, sample_frac, iters=20):
    s = max(1, int(x.shape[0] * sample_frac))
    gx, gy = x[:s], y[:s]
    params = model.init(jax.random.PRNGKey(0))

    def grad_fn(p, batch):
        bx, by = batch
        return jax.grad(lambda q: model.loss(q, bx, by))(p)

    f = jax.jit(lambda p: guiding_update(p, (gx, gy), grad_fn, 0.01, 1))
    jax.block_until_ready(f(params))
    t0 = time.time()
    for _ in range(iters):
        out = f(params)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    mx, my = make_mnist_like(jax.random.PRNGKey(0), 300)
    cx, cy = make_cifar_like(jax.random.PRNGKey(0), 300)
    cases = [("softmax_regression", softmax_regression(), mx, my),
             ("mlp3", mlp3(), mx, my),
             ("small_cnn", small_cnn(), cx, cy)]
    for frac in (0.01, 0.03):
        for name, model, x, y in cases:
            us = _measure_guide_us(model, x, y, frac)
            n = Enclave.max_clients(
                guide_flops=us * 1e-6 * 50e9,     # convert measured time
                client_step_seconds=EDGE_STEP_SECONDS[name])
            emit(f"fig9/{int(frac*100)}pct/{name}/clients_per_tee", us, n)
