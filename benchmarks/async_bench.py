"""Async federated rounds benchmark — faults, staleness, one dispatch.

The paper's synchronous round waits for every selected client; real
federations do not get that luxury — clients drop out, straggle, and
ship corrupted updates.  PR 10 moves all of that *inside* the compiled
scan (fl/faults.py, DESIGN.md §13): a precomputed (R, N) cohort-mask
chain rides the scenario operands, per-round fault draws reuse the
round's selection key, and late updates wait in an O(buffer·D) carry
slab until they fold through the same AggState monoid as live ones.
This bench makes the robustness claims *measured* numbers, for an
N=256 federation on the streaming diversefl fold (mlp3, D ≈ 34k,
``client_chunk=64``):

* **working set** — peak XLA temp of the AOT-compiled async segment
  (intermittent corruption, and the straggler config with a 32-slot
  staleness buffer — the O(buffer·D) slab is the new memory term) vs
  the 512 MB enclave envelope;
* **dispatch discipline** — a full async training run counted at the
  ``repro.fl.simulator.host_sync`` choke point under a d2h transfer
  guard (dispatch_bench style): cohorts, fault draws and staleness
  buffering must not add a single host sync;
* **trivial-async bitwise** — ``cohort_participation=1.0``, no
  faults, ``staleness_buffer=0`` threads the async carry but must
  reproduce the PR-9 engine path bit for bit: history (accuracy,
  detection rates, per-round criterion logs) and final params;
* **robustness** — DiverseFL under 20% intermittent NaN-burst
  corruption (plus the sign-flip Byzantine attack it already faces)
  vs fault-free OracleSGD: the non-finite guard + Eq. 6 criterion
  must hold final accuracy within one point of the oracle;
* **staleness accounting** — a straggler run with a bounded buffer:
  the audit chain's ``stale_{buffered,folded,expired}`` entries are
  recounted from the exported telemetry and must balance.

Acceptance (CI ``async-smoke``):

* both async segments compile under the 512 MB envelope;
* the async training run syncs the host exactly once;
* the trivial-async run is bitwise equal to the baseline engine path;
* faulty DiverseFL final accuracy >= fault-free OracleSGD - 0.01;
* the straggler run completes finite and folds stale updates.

  PYTHONPATH=src python -m benchmarks.async_bench [--smoke]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

MEM_ENVELOPE_MB = 512.0
N_CLIENTS = 256
CHUNK = 64
DIM, HIDDEN, N_CLASSES, M, PER_CLIENT = 256, 128, 10, 5, 6
FAULT_RATE = 0.2
BUFFER = 32


def _build(rounds: int, *, aggregator: str = "diversefl", **knobs):
    from repro.core.attacks import AttackConfig
    from repro.data import FederatedData, make_classification
    from repro.data.partition import partition_sorted_shards
    from repro.fl import FLConfig, Federation, RoundEngine
    from repro.fl.small_models import mlp3

    x, y = make_classification(jax.random.PRNGKey(0),
                               N_CLIENTS * PER_CLIENT, N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, N_CLASSES, DIM)
    model = mlp3(input_dim=DIM, n_classes=N_CLASSES, hidden=HIDDEN)
    cfg = FLConfig(n_clients=N_CLIENTS, f=N_CLIENTS // 5,
                   aggregator=aggregator,
                   attack=AttackConfig(kind="sign_flip"), batch_size=M,
                   eval_every=rounds, l2=0.0, client_chunk=CHUNK,
                   streaming=True, **knobs)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    engine = RoundEngine(model, fed, cfg, eval_every=rounds,
                         client_chunk=CHUNK)
    params = model.init(jax.random.PRNGKey(1))
    return model, fed, cfg, engine, params


def _compile_segment(engine, params, rounds: int):
    """AOT-compile one scan segment (carry-shaped: async configs thread
    the (params, astate) carry) — nothing executes."""
    _key, subs = engine._segment_keys(jax.random.PRNGKey(0), rounds)
    lrs = jnp.zeros((rounds,), jnp.float32)
    carry = engine.init_carry(params)
    return engine._segment.lower(carry, subs, lrs, False, None,
                                 engine.default_scenario).compile()


def _flat(params):
    return np.concatenate(
        [np.asarray(v).ravel() for v in jax.tree.leaves(params)])


def _train(model, fed, cfg, *, count_syncs: bool = False):
    """One full training through the public entry; optionally counts
    device->host materializations at the host_sync choke point under a
    transfer guard (dispatch_bench's counted-not-asserted discipline)."""
    import repro.fl.simulator as sim
    from repro.optim import inv_sqrt_lr

    sched = inv_sqrt_lr(0.05)
    if not count_syncs:
        return sim.run_federated_training(model, fed, cfg, sched), None
    counter = {"n": 0}
    orig = sim.host_sync

    def counting(tree):
        counter["n"] += 1
        return orig(tree)

    sim.host_sync = counting
    try:
        with jax.transfer_guard_device_to_host("disallow_explicit"):
            hist = sim.run_federated_training(model, fed, cfg, sched)
    finally:
        sim.host_sync = orig
    return hist, counter["n"]


def run(smoke: bool = False):
    from repro.fl.faults import FaultConfig

    from .common import emit, write_report

    seg_rounds = 1 if smoke else 2
    acc_rounds = 12 if smoke else 40
    intermittent = FaultConfig(kind="intermittent", rate=FAULT_RATE,
                               mode="nan")
    straggler = FaultConfig(kind="straggler", rate=FAULT_RATE, delay=1)

    # -- working set: async segments vs the enclave envelope ------------
    temps = {}
    for label, knobs in (
            ("intermittent", dict(fault=intermittent,
                                  cohort_participation=0.9)),
            ("straggler_buffered", dict(fault=straggler,
                                        cohort_participation=0.9,
                                        staleness_buffer=BUFFER)),
    ):
        model, fed, cfg, engine, params = _build(seg_rounds, **knobs)
        compiled = _compile_segment(engine, params, seg_rounds)
        temp_mb = compiled.memory_analysis().temp_size_in_bytes / 1e6
        temps[label] = round(temp_mb, 1)
        emit(f"async/segment_{label}_n{N_CLIENTS}", 0.0,
             f"xla_temp={temp_mb:.0f}MB")
    under_envelope = all(t <= MEM_ENVELOPE_MB for t in temps.values())

    # -- dispatch discipline: the async run syncs exactly once ----------
    model, fed, cfg, engine, params = _build(
        acc_rounds, fault=intermittent, cohort_participation=0.9)
    t0 = time.time()
    hist_async, syncs = _train(model, fed, cfg, count_syncs=True)
    dt = time.time() - t0
    emit(f"async/run_n{N_CLIENTS}", dt / acc_rounds * 1e6,
         f"host_syncs={syncs}|acc={hist_async['final_acc']:.4f}")

    # -- trivial-async bitwise vs the baseline engine path --------------
    model, fed, cfg_b, _eng, _p = _build(acc_rounds)
    hist_base, _ = _train(model, fed, cfg_b)
    model, fed, cfg_t, _eng, _p = _build(
        acc_rounds, cohort_participation=1.0)
    hist_triv, _ = _train(model, fed, cfg_t)
    bitwise = bool(np.array_equal(_flat(hist_triv["params"]),
                                  _flat(hist_base["params"])))
    for k in ("round", "acc", "mask_tpr", "mask_fpr", "c1c2"):
        if k in hist_base:
            bitwise &= bool(np.array_equal(np.asarray(hist_base[k]),
                                           np.asarray(hist_triv[k])))
    emit(f"async/trivial_bitwise_n{N_CLIENTS}", 0.0, f"bitwise={bitwise}")

    # -- robustness: faulty DiverseFL vs fault-free OracleSGD -----------
    model, fed, cfg_o, _eng, _p = _build(acc_rounds, aggregator="oracle")
    hist_oracle, _ = _train(model, fed, cfg_o)
    acc_faulty = float(hist_async["final_acc"])
    acc_oracle = float(hist_oracle["final_acc"])
    within = acc_faulty >= acc_oracle - 0.01
    emit(f"async/diversefl_faulty_vs_oracle_n{N_CLIENTS}", 0.0,
         f"faulty={acc_faulty:.4f}|oracle={acc_oracle:.4f}"
         f"|within_1pt={within}")

    # -- staleness accounting: straggler run folds its late updates -----
    model, fed, cfg_s, _eng, _p = _build(
        acc_rounds, fault=straggler, cohort_participation=0.9,
        staleness_buffer=BUFFER, telemetry=True)
    hist_strag, _ = _train(model, fed, cfg_s)
    stale = {"stale_buffered": 0, "stale_folded": 0, "stale_expired": 0}
    for e in fed.server.audit.entries:
        if e["kind"] in stale:
            stale[e["kind"]] += int(e["data"]["count"])
    strag_finite = bool(np.isfinite(_flat(hist_strag["params"])).all())
    # buffered updates either landed or are still in flight at the end;
    # expiry only claims what the buffer refused
    balanced = (stale["stale_folded"] <= stale["stale_buffered"]
                and stale["stale_folded"] > 0)
    emit(f"async/straggler_n{N_CLIENTS}", 0.0,
         "|".join(f"{k}={v}" for k, v in stale.items())
         + f"|finite={strag_finite}")

    acceptance = {
        "async_segments_under_envelope": bool(under_envelope),
        "one_host_sync": syncs == 1,
        "trivial_async_bitwise": bitwise,
        "faulty_diversefl_within_1pt_of_oracle": bool(within),
        "straggler_run_finite": strag_finite,
        "stale_accounting_balanced": bool(balanced),
    }
    return write_report("async", smoke=smoke, acceptance=acceptance,
                        aggregator="diversefl", envelope_mb=MEM_ENVELOPE_MB,
                        n_clients=N_CLIENTS, client_chunk=CHUNK,
                        rounds=acc_rounds, fault_rate=FAULT_RATE,
                        staleness_buffer=BUFFER, xla_temp_mb=temps,
                        host_syncs=syncs,
                        sec_per_round=round(dt / acc_rounds, 3),
                        accuracy={"diversefl_faulty": round(acc_faulty, 4),
                                  "oracle_faultfree": round(acc_oracle, 4)},
                        stale_counts=stale)


def main():
    from .common import smoke_main
    smoke_main(run)


if __name__ == "__main__":
    main()
