"""Shared benchmark scaffolding: timed FL runs, CSV emission, reports.

Every benchmark module maps to one paper table/figure and emits rows
``name,us_per_call,derived`` where us_per_call is wall-time per FL round
(or per op call) and derived is the figure's metric (accuracy, ratio...).
Acceptance-gated suites (benchmarks/run.py) additionally write a
``BENCH_<name>.json`` report through :func:`write_report` and exit
through :func:`smoke_main` — one definition of the gating contract for
all of them.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import jax

from repro.core.attacks import AttackConfig
from repro.data import FederatedData, make_mnist_like, partition_sorted_shards
from repro.fl import FLConfig, Federation, run_federated_training, telemetry
from repro.fl.small_models import softmax_regression
from repro.optim import inv_sqrt_lr

ROWS = []

REPO_ROOT = Path(__file__).resolve().parents[1]

# bump when the report layout changes shape (readers key on this)
REPORT_SCHEMA_VERSION = 2


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT, check=True,
            capture_output=True, text=True, timeout=10).stdout.strip()
    except Exception:
        return "unknown"


def provenance() -> dict:
    """What produced this report: the reproducibility stamp every
    BENCH_*.json carries (a snapshot without these is uncomparable —
    you cannot tell a regression from a toolchain change)."""
    return {
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def write_report(name: str, *, smoke: bool, acceptance: dict,
                 **sections) -> dict:
    """Assemble and write one suite's ``BENCH_<name>.json`` report.

    The shared tail of every acceptance-gated bench: the report is
    ``{"schema_version", "mode", "provenance", **sections,
    "acceptance"}`` with acceptance values coerced to plain bools (numpy
    bools are not JSON), written with the repo-standard 2-space indent +
    trailing newline, and the path announced on stderr.  Every report
    stamps the schema version, git SHA, and jax/backend versions
    (:func:`provenance`); when the flight recorder is live (smoke_main
    runs each bench under ``telemetry.recording()``) the run's trace is
    attached as compact span/event counts.  Returns the report dict so
    ``run()`` can hand it to :func:`smoke_main` for the exit-code
    gate."""
    report = {"schema_version": REPORT_SCHEMA_VERSION,
              "mode": "smoke" if smoke else "full",
              "provenance": provenance(),
              **sections,
              "acceptance": {k: bool(v) for k, v in acceptance.items()}}
    rec = telemetry.get_recorder()
    if rec.enabled:
        report["trace"] = rec.counts()
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {path}", file=sys.stderr, flush=True)
    return report


def smoke_main(run_fn) -> None:
    """The shared ``main()`` of every acceptance-gated bench (engine,
    streaming, dispatch): parse ``--smoke``, run under the flight
    recorder (so write_report can attach the trace), print the
    acceptance dict, exit non-zero when a smoke acceptance fails — one
    definition instead of a copy per module."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes; exit 1 on failed acceptance")
    args = ap.parse_args()
    with telemetry.recording():
        report = run_fn(smoke=args.smoke)
    ok = all(report["acceptance"].values())
    print(f"acceptance: {report['acceptance']}", flush=True)
    if args.smoke and not ok:
        sys.exit(1)


def emit(name: str, us_per_call: float, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def mnist_like_federation(n_clients=23, n_train=4600, n_test=800, seed=0):
    x, y = make_mnist_like(jax.random.PRNGKey(seed), n_train)
    tx, ty = make_mnist_like(jax.random.PRNGKey(seed + 9), n_test)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, n_clients), 10)
    return data, tx, ty


def timed_fl_run(model, data, tx, ty, aggregator: str, attack: AttackConfig,
                 rounds: int = 60, lr0: float = 0.05, seed: int = 2, **kw):
    cfg = FLConfig(n_clients=data.n_clients, rounds=rounds,
                   aggregator=aggregator, attack=attack, batch_size=50,
                   eval_every=rounds, **kw)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(seed))
    t0 = time.time()
    hist = run_federated_training(model, fed, cfg, inv_sqrt_lr(lr0))
    dt = time.time() - t0
    return hist, fed, dt / rounds * 1e6
