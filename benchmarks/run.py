"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  python -m benchmarks.run               # everything (full rounds)
  python -m benchmarks.run --quick       # reduced rounds (CI)
  python -m benchmarks.run --only fig3   # one table/figure
  python -m benchmarks.run async --smoke # one suite, acceptance-gated:
                                         # reduced sizes AND exit 1 when
                                         # any written acceptance fails

Suites are declared in the ``SUITES`` registry below: ``(name, module,
knob)`` where ``knob`` names the reduced-size keyword the module's
``run()`` accepts under ``--quick`` (``"rounds"`` for the paper-figure
benches, ``"smoke"`` for the acceptance-gated system benches, ``None``
for fixed-size ones) — adding a bench is one line, not a copied block.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

QUICK_ROUNDS = 25

# (suite name, benchmarks.<module>, quick-mode knob)
SUITES = (
    ("fig2", "fig2_criteria", "rounds"),
    ("fig3", "fig3_softmax", "rounds"),
    ("fig456", "fig456_nn", "rounds"),
    ("fig7", "fig7_backdoor", "rounds"),
    ("fig8", "fig8_poisoning", None),
    ("fig9", "fig9_timing", None),
    ("tab234", "tab234_f17", "rounds"),
    ("ablation", "ablation", "rounds"),
    ("kernels", "kernel_bench", None),
    ("engine", "engine_bench", "smoke"),
    ("streaming", "streaming_bench", "smoke"),
    ("tree_agg", "tree_agg_bench", "smoke"),
    ("dispatch", "dispatch_bench", "smoke"),
    ("sweep", "sweep_bench", "smoke"),
    ("comm", "comm_bench", "smoke"),
    ("async", "async_bench", "smoke"),
    ("model_fl", "model_fl_bench", "smoke"),
    ("roofline", "roofline", None),
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("suite", nargs="?", default=None,
                    help="suite name substring (same filter as --only)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes like --quick, but exit 1 when a "
                         "suite errors or writes a failed acceptance")
    args = ap.parse_args()
    only = args.only or args.suite
    reduced = args.quick or args.smoke

    failed = False
    print("name,us_per_call,derived")
    for name, module, knob in SUITES:
        if only and only not in name:
            continue
        kwargs = {}
        if knob == "rounds" and reduced:
            kwargs["rounds"] = QUICK_ROUNDS
        elif knob == "smoke":
            kwargs["smoke"] = reduced
        t0 = time.time()
        try:  # import inside: a broken module must not abort the sweep
            mod = importlib.import_module(f".{module}", __package__)
            report = mod.run(**kwargs)
            if isinstance(report, dict) and "acceptance" in report:
                print(f"# {name} acceptance: {report['acceptance']}",
                      file=sys.stderr, flush=True)
                failed |= not all(report["acceptance"].values())
        except Exception as e:  # keep the harness going; surface the failure
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            failed = True
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr,
              flush=True)
    if args.smoke and failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
