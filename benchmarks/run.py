"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  python -m benchmarks.run               # everything (full rounds)
  python -m benchmarks.run --quick       # reduced rounds (CI)
  python -m benchmarks.run --only fig3   # one table/figure
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from . import (ablation, engine_bench, fig2_criteria, fig3_softmax,
                   fig456_nn, fig7_backdoor, fig8_poisoning, fig9_timing,
                   kernel_bench, roofline, streaming_bench, tab234_f17)

    r = 25 if args.quick else None
    suites = [
        ("fig2", lambda: fig2_criteria.run(**({"rounds": r} if r else {}))),
        ("fig3", lambda: fig3_softmax.run(**({"rounds": r} if r else {}))),
        ("fig456", lambda: fig456_nn.run(**({"rounds": r} if r else {}))),
        ("fig7", lambda: fig7_backdoor.run(**({"rounds": r} if r else {}))),
        ("fig8", fig8_poisoning.run),
        ("fig9", fig9_timing.run),
        ("tab234", lambda: tab234_f17.run(**({"rounds": r} if r else {}))),
        ("ablation", lambda: ablation.run(**({"rounds": r} if r else {}))),
        ("kernels", kernel_bench.run),
        ("engine", lambda: engine_bench.run(smoke=args.quick)),
        ("streaming", lambda: streaming_bench.run(smoke=args.quick)),
        ("roofline", roofline.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the harness going; surface the failure
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr,
              flush=True)


if __name__ == "__main__":
    main()
