"""Kernel-layer microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode (not
representative of TPU timing), so the timed path is the XLA reference
implementation; derived reports achieved GB/s plus the analytic
HBM-traffic ratio the fused kernel saves on TPU (similarity: one operand
pass instead of three)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.diversefl import DiverseFLConfig, diversefl_mask
from repro.kernels import ref

from .common import emit


def _time(f, *args, iters=10):
    jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)

    # similarity: 23 clients x 2M params (3-NN scale)
    n, d = 23, 2_000_000
    z = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    f = jax.jit(ref.similarity_ref)
    us = _time(f, z, g)
    gbs = (2 * n * d * 4) / (us * 1e-6) / 1e9
    emit("kernel/similarity_xla_ref", us, f"{gbs:.1f}GBps|fused_saves=3x_reads")

    # fused masked aggregation (DiverseFL Step 4+5, Eq. 6): the XLA
    # baseline re-reads U for the three similarity reductions AND the
    # select+mean (5 operand passes: U x3, G x2); the fused Pallas pair
    # (similarity kernel + masked_agg kernel) does U x2, G x1.
    dcfg = DiverseFLConfig()

    def step45_baseline(zz, gg):
        s = ref.similarity_ref(zz, gg)
        mask = diversefl_mask(s[:, 0], s[:, 1], s[:, 2], dcfg)
        return ref.masked_agg_ref(zz, mask)

    f = jax.jit(step45_baseline)
    us = _time(f, z, g)
    base_mb = 5 * n * d * 4 / 1e6            # U read 3x + G read 2x
    fused_mb = 3 * n * d * 4 / 1e6           # U read 2x + G read 1x
    emit("kernel/masked_agg_step45_xla_ref", us,
         f"{(base_mb/1e3)/(us*1e-6):.1f}GBps|hbm_passes=U:2+G:1_vs_U:3+G:2"
         f"|bytes={fused_mb:.0f}MB_vs_{base_mb:.0f}MB")

    # robust aggregation: median over 23 x 2M
    f = jax.jit(ref.median_ref)
    us = _time(f, z)
    emit("kernel/median_xla_ref", us, f"{(n*d*4)/(us*1e-6)/1e9:.1f}GBps")

    # flash attention: 4k sequence
    B, H, S, dh = 1, 8, 1024, 128
    q = jnp.asarray(rng.normal(size=(B, H, S, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, H, S, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, H, S, dh)).astype(np.float32))
    f = jax.jit(lambda *a: ref.flash_attention_ref(*a))
    us = _time(f, q, k, v, iters=3)
    fl = 4 * B * H * S * S * dh / 2
    emit("kernel/attention_xla_ref_1k", us, f"{fl/(us*1e-6)/1e9:.1f}GFLOPs")

    # mamba scan: 64-layer falcon shape slice
    B, S, di, n_st = 1, 512, 256, 16
    da = jnp.asarray(np.exp(-np.abs(rng.normal(size=(B, S, di, n_st)))).astype(np.float32))
    dbx = jnp.asarray(rng.normal(size=(B, S, di, n_st)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(B, S, n_st)).astype(np.float32))
    f = jax.jit(ref.mamba_scan_ref)
    us = _time(f, da, dbx, c, iters=3)
    emit("kernel/mamba_scan_xla_ref", us,
         f"{(9*B*S*di*n_st)/(us*1e-6)/1e9:.1f}GFLOPs")
