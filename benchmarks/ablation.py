"""Beyond-paper ablations:
  (a) epsilon sensitivity — sweep the C2 band (eps2, eps3) against a
      stealthy scaling attack z*1.5 that hides inside wide bands,
  (b) partial participation — the paper's |S^i| = C <= N selection,
  (c) Dirichlet(alpha) heterogeneity instead of sort-sharding.
"""
from __future__ import annotations

import jax

from repro.core.attacks import AttackConfig
from repro.core.diversefl import DiverseFLConfig
from repro.data import (FederatedData, make_mnist_like, partition_dirichlet)
from repro.fl.small_models import softmax_regression

from .common import emit, mnist_like_federation, timed_fl_run


def run(rounds: int = 30):
    data, tx, ty = mnist_like_federation()
    model = softmax_regression()

    # (a) epsilon sensitivity vs stealthy x1.5 scaling
    acfg = AttackConfig(kind="scale", scale=1.5)
    for eps2, eps3 in [(0.5, 2.0), (0.25, 4.0), (0.8, 1.25), (0.9, 1.1)]:
        hist, _, us = timed_fl_run(
            model, data, tx, ty, "diversefl", acfg, rounds=rounds,
            dfl=DiverseFLConfig(eps2=eps2, eps3=eps3))
        emit(f"ablation/eps/{eps2}-{eps3}/acc", us, f"{hist['final_acc']:.4f}")
        emit(f"ablation/eps/{eps2}-{eps3}/tpr", us,
             f"{hist['mask_tpr'][-1]:.2f}")

    # (b) partial participation C <= N
    acfg = AttackConfig(kind="sign_flip")
    for part in (1.0, 0.5):
        hist, _, us = timed_fl_run(model, data, tx, ty, "diversefl", acfg,
                                   rounds=rounds, participation=part)
        emit(f"ablation/participation/{part}/acc", us,
             f"{hist['final_acc']:.4f}")
        emit(f"ablation/participation/{part}/tpr", us,
             f"{hist['mask_tpr'][-1]:.2f}")

    # (c) Dirichlet heterogeneity
    x, y = make_mnist_like(jax.random.PRNGKey(0), 4600)
    for alpha in (0.1, 1.0):
        datad = FederatedData.from_partitions(
            partition_dirichlet(x, y, 23, alpha=alpha), 10)
        hist, _, us = timed_fl_run(model, datad, tx, ty, "diversefl", acfg,
                                   rounds=rounds)
        emit(f"ablation/dirichlet/{alpha}/acc", us,
             f"{hist['final_acc']:.4f}")
        emit(f"ablation/dirichlet/{alpha}/tpr", us,
             f"{hist['mask_tpr'][-1]:.2f}")
