"""One-dispatch training benchmark — host syncs per run, measured.

The paper's enclave performs aggregation *and* evaluation inside the
TEE; the simulation's analogue is keeping a whole training run device-
resident.  This bench measures exactly that, for a 10-segment run at
N=1024 clients, ``client_chunk=64``:

* **host_eval** — the legacy per-segment loop: one scan dispatch per
  eval segment, then the jitted eval and a host sync of its metrics —
  10 syncs for 10 segments;
* **one_dispatch** — ``RoundEngine.run_training``: the outer scan runs
  every segment *and* its eval tail on device, and the host syncs once,
  at the end, when the metric buffer is fetched.

The sync count is **counted, not asserted from the code**: every
device→host materialization in the simulator flows through the single
``repro.fl.simulator.host_sync`` choke point, which this bench wraps
with a counter — and the timed runs execute under
``jax.transfer_guard_device_to_host("disallow_explicit")``, so on
backends where device memory is distinct from host memory (GPU/TPU) a
host read that bypasses the choke point raises instead of hiding.  (On
the CPU backend arrays are host-resident and the guard never fires —
there the counter *is* the measurement; the guard is kept so the same
bench is load-bearing on accelerators.)  A multi-segment one-dispatch
run exceeding one final sync fails the acceptance (CI
``dispatch-smoke``).

The donation section closes the ROADMAP "Donation on accelerator"
measurement gap: the training program is AOT-compiled with the carry
donation forced on and off (`FLConfig.donate` → ``RoundEngine``) and
the XLA ``memory_analysis`` working-set numbers of both variants are
recorded (on CPU, where XLA cannot donate, the delta documents itself
as zero — the bench records the backend).

  PYTHONPATH=src python -m benchmarks.dispatch_bench [--smoke]
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

N_CLIENTS = 1024
CHUNK = 64
SEGMENTS = 10
DIM, N_CLASSES, PER_CLIENT, M = 8, 4, 8, 1


def _build(eval_every: int, rounds: int, **cfg_kw):
    from repro.core.attacks import AttackConfig
    from repro.data import FederatedData, make_classification
    from repro.data.partition import partition_sorted_shards
    from repro.fl import FLConfig, Federation
    from repro.fl.small_models import softmax_regression

    x, y = make_classification(jax.random.PRNGKey(0),
                               N_CLIENTS * PER_CLIENT, N_CLASSES, DIM)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N_CLIENTS), N_CLASSES)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, N_CLASSES, DIM)
    model = softmax_regression(input_dim=DIM, n_classes=N_CLASSES)
    cfg = FLConfig(n_clients=N_CLIENTS, f=N_CLIENTS // 5,
                   aggregator="diversefl",
                   attack=AttackConfig(kind="backdoor", source_class=1,
                                       target_class=2),
                   batch_size=M, rounds=rounds, eval_every=eval_every,
                   l2=0.0, client_chunk=CHUNK, **cfg_kw)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    return model, fed, cfg


def _timed_run(model, fed, cfg, *, host_eval: bool, reps: int):
    """Best-of-reps seconds for one full training run, plus the host
    sync count of the *timed* run (warmup excluded), measured at the
    simulator's host_sync choke point under a d2h transfer guard."""
    import repro.fl.simulator as sim
    from repro.fl import RoundEngine
    from repro.optim import inv_sqrt_lr

    counter = {"n": 0}
    orig = sim.host_sync

    def counting(tree):
        counter["n"] += 1
        return orig(tree)

    sched = inv_sqrt_lr(0.05)
    engine = RoundEngine(model, fed, cfg)        # compiled once, timed reps
    best, syncs, hist = np.inf, None, None
    sim.host_sync = counting
    try:
        for rep in range(reps + 1):              # rep 0 = compile warmup
            counter["n"] = 0
            t0 = time.time()
            with jax.transfer_guard_device_to_host(
                    "allow" if rep == 0 else "disallow_explicit"):
                hist = sim.run_federated_training(
                    model, fed, cfg, sched, host_eval=host_eval,
                    engine=engine)
            dt = time.time() - t0
            if rep > 0:
                best, syncs = min(best, dt), counter["n"]
    finally:
        sim.host_sync = orig
    return best, syncs, hist


def _donation_section(eval_every: int, rounds: int):
    """AOT-compile the one-dispatch program with donation forced on/off
    and record the XLA memory_analysis working-set numbers of each."""
    from repro.fl import RoundEngine

    out = {"backend": jax.default_backend(),
           "donation_supported": jax.default_backend() != "cpu"}
    S, T = rounds // eval_every, eval_every
    model, fed, cfg = _build(eval_every, rounds)   # one federation, two
    params = model.init(jax.random.PRNGKey(cfg.seed + 1))   # compiles
    for label, donate in (("donate_on", True), ("donate_off", False)):
        engine = RoundEngine(model, fed, cfg, donate=donate)
        _, subs = engine._segment_keys(jax.random.PRNGKey(0), rounds)
        lowered = engine._training.lower(
            params, subs.reshape((S, T) + subs.shape[1:]),
            jnp.zeros((S, T), jnp.float32), engine.default_scenario)
        stats = lowered.compile().memory_analysis()
        out[label] = {
            "temp_mb": round(stats.temp_size_in_bytes / 1e6, 2),
            "argument_mb": round(stats.argument_size_in_bytes / 1e6, 2),
            "output_mb": round(stats.output_size_in_bytes / 1e6, 2),
            "alias_mb": round(stats.alias_size_in_bytes / 1e6, 2),
        }
    on, off = out["donate_on"], out["donate_off"]
    out["working_set_delta_mb"] = round(
        (off["temp_mb"] + off["argument_mb"])
        - (on["temp_mb"] + on["argument_mb"] - on["alias_mb"]), 2)
    return out


def run(smoke: bool = False):
    from .common import emit, write_report
    eval_every = 1 if smoke else 5
    rounds = SEGMENTS * eval_every
    # the smoke runs are ~15 ms each, so the wall-clock ratio is noise-
    # sensitive (idle box: 1.5-2.3x; contended: as low as ~1.3x against
    # the 1.3x gate).  Best-of-6 gives each path several chances to hit
    # an undisturbed window — the robust gates are the sync counts and
    # the bitwise history check, the ratio gate guards against gross
    # regressions.
    reps = 6 if smoke else 3

    model, fed, cfg = _build(eval_every, rounds)
    t_host, syncs_host, h_host = _timed_run(model, fed, cfg,
                                            host_eval=True, reps=reps)
    t_one, syncs_one, h_one = _timed_run(model, fed, cfg,
                                         host_eval=False, reps=reps)
    rps_host, rps_one = rounds / t_host, rounds / t_one
    speedup = rps_one / rps_host
    history_keys = ("round", "acc", "main_acc", "backdoor_acc",
                    "mask_tpr", "mask_fpr")
    # same jitted metrics on both paths -> the histories must agree
    # bitwise; a drift here means the in-scan eval rotted
    bitwise = all(h_host[k] == h_one[k] for k in history_keys)

    # the flight-recorder gate (ISSUE 8): the per-round telemetry block
    # rides the existing metric buffer, so a telemetry-enabled run must
    # still reach the host in the same single sync — and must not
    # perturb a single history bit
    cfg_tel = dataclasses.replace(cfg, telemetry=True)
    _, syncs_tel, h_tel = _timed_run(model, fed, cfg_tel,
                                     host_eval=False, reps=1)
    tel_bitwise = all(h_tel[k] == h_one[k] for k in history_keys)

    emit(f"dispatch/host_eval_n{N_CLIENTS}", 1e6 / rps_host,
         f"{rps_host:.1f}rps|syncs={syncs_host}")
    emit(f"dispatch/one_dispatch_n{N_CLIENTS}", 1e6 / rps_one,
         f"{rps_one:.1f}rps|syncs={syncs_one}|speedup={speedup:.2f}x")
    emit(f"dispatch/telemetry_n{N_CLIENTS}", 0.0,
         f"syncs={syncs_tel}|bitwise={tel_bitwise}")

    donation = _donation_section(eval_every, rounds)
    acceptance = {
        "one_dispatch_single_sync": syncs_one == 1,
        "host_eval_syncs_per_segment": syncs_host == SEGMENTS,
        "in_scan_eval_matches_host_eval": bool(bitwise),
        "speedup_ge_1_3x": speedup >= 1.3,
        "telemetry_single_sync": syncs_tel == 1,
        "telemetry_bitwise_history": bool(tel_bitwise),
    }
    return write_report(
        "dispatch", smoke=smoke, acceptance=acceptance,
        n_clients=N_CLIENTS, client_chunk=CHUNK,
        segments=SEGMENTS, eval_every=eval_every, rounds=rounds,
        host_eval={"sec_per_run": round(t_host, 3),
                   "rounds_per_sec": round(rps_host, 1),
                   "host_syncs": syncs_host},
        one_dispatch={"sec_per_run": round(t_one, 3),
                      "rounds_per_sec": round(rps_one, 1),
                      "host_syncs": syncs_one},
        telemetry={"host_syncs": syncs_tel,
                   "history_bitwise": bool(tel_bitwise)},
        speedup=round(speedup, 2),
        donation=donation)


def main():
    from .common import smoke_main
    smoke_main(run)


if __name__ == "__main__":
    main()
