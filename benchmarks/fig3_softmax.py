"""Fig. 3 — softmax regression (convex) on non-IID MNIST-like data:
final test accuracy per (aggregation scheme x attack).  Paper claim:
DiverseFL ~= OracleSGD and >= all baselines in every scenario."""
from __future__ import annotations

from repro.core.attacks import AttackConfig
from repro.fl.small_models import softmax_regression
from repro.fl.rsa import run_rsa
from repro.fl.simulator import FLConfig, Federation
from repro.optim import inv_sqrt_lr

from .common import emit, mnist_like_federation, timed_fl_run

SCHEMES = ("oracle", "diversefl", "median", "resampling", "fltrust",
           "krum", "bulyan")
ATTACKS = ("none", "gaussian", "sign_flip", "same_value", "label_flip")


def run(rounds: int = 50, schemes=SCHEMES, attacks=ATTACKS):
    data, tx, ty = mnist_like_federation()
    model = softmax_regression()
    for attack in attacks:
        acfg = AttackConfig(kind=attack, sigma=1e4)
        for scheme in schemes:
            hist, _, us = timed_fl_run(model, data, tx, ty, scheme, acfg,
                                       rounds=rounds)
            emit(f"fig3/{attack}/{scheme}", us, f"{hist['final_acc']:.4f}")
        # RSA (protocol baseline, convex setting only)
        import time, jax
        cfg = FLConfig(n_clients=data.n_clients, rounds=rounds,
                       aggregator="mean", attack=acfg, batch_size=50,
                       eval_every=rounds)
        fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
        t0 = time.time()
        # RSA needs its own tuning: at delta=0.25 (the paper's MNIST value,
        # 1000 rounds at lr 0.001/sqrt(i)) the sign-consensus term diverges
        # at our faster schedule; delta=0.05 is the stable equivalent for
        # this round budget.
        h = run_rsa(model, fed, cfg, inv_sqrt_lr(0.02), delta=0.05)
        emit(f"fig3/{attack}/rsa", (time.time() - t0) / rounds * 1e6,
             f"{h['final_acc']:.4f}")
