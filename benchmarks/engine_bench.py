"""Round-engine benchmark — seed per-round loop vs the scan engine.

Measures rounds/sec for {seed loop, scan engine} x {N=23, 256, 1024}
and records the results to ``BENCH_engine.json`` at the repo root.

Two sections:

* **dispatch** — model compute is kept negligible (dim-8 softmax
  regression, m=1) so rounds/sec measures the *round-loop machinery*:
  the seed path pays an eager ``jax.random.split``, an eager lr-schedule
  evaluation, a jitted dispatch and per-round log materialization every
  round; the engine pays one dispatch per ``eval_every``-round scan
  segment.  Both paths run the identical round body
  (fl/engine.make_round_body) with the repo-standard inv-sqrt schedule.
* **memory** — a 1024-client federation on an MLP whose unchunked
  vmapped local-training working set exceeds the memory envelope; the
  engine completes a scan segment in ``client_chunk``-sized blocks at
  O(chunk x model) working memory, while the unchunked path is skipped
  (recorded, not silently dropped).

``--smoke`` (CI): tiny round counts, 2 engine segments per repetition,
and a non-zero exit code when the acceptance criteria fail — the scan
path cannot silently rot.

  PYTHONPATH=src python -m benchmarks.engine_bench [--smoke]
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attacks import AttackConfig
from repro.data import FederatedData, make_classification
from repro.data.partition import partition_sorted_shards
from repro.fl import FLConfig, Federation, RoundEngine
from repro.fl.simulator import _build_round_step
from repro.fl.small_models import mlp3, softmax_regression
from repro.optim import inv_sqrt_lr

from .common import emit, write_report

# local-training working set the unchunked vmap path materializes per
# client beyond the (N, D) update matrix the registry needs anyway:
# params copy + grads + update (~3x model) plus the local batch.
MEM_ENVELOPE_MB = 512.0


def _tiny_federation(n_clients: int, eval_every: int, *, dim=8, n_classes=4,
                     per_client=8, batch_size=1, client_chunk=None):
    x, y = make_classification(jax.random.PRNGKey(0), n_clients * per_client,
                               n_classes, dim)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, n_clients), n_classes)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, n_classes, dim)
    model = softmax_regression(input_dim=dim, n_classes=n_classes)
    cfg = FLConfig(n_clients=n_clients, f=max(1, n_clients // 5),
                   aggregator="diversefl",
                   attack=AttackConfig(kind="sign_flip"),
                   batch_size=batch_size, eval_every=eval_every, l2=0.0,
                   client_chunk=client_chunk)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    return model, fed, cfg


def _block(params):
    jax.block_until_ready(jax.tree.leaves(params)[0])


def time_seed_loop(model, fed, cfg, rounds: int, reps: int) -> float:
    """Best-of-reps rounds/sec for the per-round jitted Python loop.

    This is the seed repo's loop verbatim: every round pays an eager
    ``jax.random.split``, an eager lr-schedule evaluation (the repo's
    standard inv-sqrt schedule), one jitted dispatch and the per-round
    log materialization."""
    sched = inv_sqrt_lr(0.05)
    step = _build_round_step(model, fed, cfg)
    params0 = model.init(jax.random.PRNGKey(cfg.seed + 1))
    best = math.inf
    for rep in range(reps + 1):                  # rep 0 = compile warmup
        key, params = jax.random.PRNGKey(cfg.seed), params0
        t0 = time.time()
        for i in range(1, rounds + 1):
            key, sub = jax.random.split(key)
            params, _logs = step(params, sub, float(sched(i)))
        _block(params)
        if rep > 0:
            best = min(best, time.time() - t0)
    return rounds / best


def time_engine(model, fed, cfg, segments: int, reps: int) -> float:
    """Best-of-reps rounds/sec for the scan engine (one dispatch/segment).

    Batches are served as per-segment stacks by the data pipeline (the
    minibatch sampling moves out of the scan into one jitted host call
    per segment), and the segment's lr vector is evaluated with one
    jitted vmap of the same schedule rather than per-round eager ops."""
    lr_of = jax.jit(jax.vmap(inv_sqrt_lr(0.05)))
    # donate=False: the reps all restart from the same params0 buffers,
    # which donation would invalidate on accelerator backends.
    engine = RoundEngine(model, fed, cfg, batch_mode="segment", donate=False)
    params0 = model.init(jax.random.PRNGKey(cfg.seed + 1))
    T = cfg.eval_every
    best = math.inf
    for rep in range(reps + 1):                  # rep 0 = compile warmup
        key, params = jax.random.PRNGKey(cfg.seed), params0
        t0 = time.time()
        for s in range(segments):
            lrs = lr_of(jnp.arange(s * T + 1, (s + 1) * T + 1))
            params, key, _logs = engine.run_segment(params, key, lrs)
        _block(params)
        if rep > 0:
            best = min(best, time.time() - t0)
    return segments * T / best


def _unchunked_working_mb(n_clients, n_params, batch_elems) -> float:
    return n_clients * (3 * n_params + batch_elems) * 4 / 1e6


def run_memory_section(smoke: bool):
    """1024 clients on an MLP: chunked engine segment vs skipped vmap."""
    N, dim, n_classes, m, per_client = 1024, 256, 10, 5, 6
    chunk = 64
    rounds = 2 if smoke else 5
    x, y = make_classification(jax.random.PRNGKey(0), N * per_client,
                               n_classes, dim)
    data = FederatedData.from_partitions(
        partition_sorted_shards(x, y, N), n_classes)
    tx, ty = make_classification(jax.random.PRNGKey(9), 64, n_classes, dim)
    model = mlp3(input_dim=dim, n_classes=n_classes, hidden=128)
    cfg = FLConfig(n_clients=N, f=N // 5, aggregator="diversefl",
                   attack=AttackConfig(kind="sign_flip"), batch_size=m,
                   eval_every=rounds, l2=0.0, client_chunk=chunk)
    fed = Federation.create(model, data, tx, ty, cfg, jax.random.PRNGKey(2))
    params = model.init(jax.random.PRNGKey(1))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    batch_elems = m * dim
    unchunked_mb = _unchunked_working_mb(N, n_params, batch_elems)
    chunked_mb = _unchunked_working_mb(chunk, n_params, batch_elems)

    out = {"n_clients": N, "model_params": int(n_params),
           "envelope_mb": MEM_ENVELOPE_MB,
           "unchunked_working_mb": round(unchunked_mb, 1),
           "chunked_working_mb": round(chunked_mb, 1),
           "client_chunk": chunk, "rounds": rounds}
    if unchunked_mb > MEM_ENVELOPE_MB:
        out["unchunked"] = (f"skipped: est {unchunked_mb:.0f}MB local-training"
                            f" working set > {MEM_ENVELOPE_MB:.0f}MB envelope")
        emit("engine/mem_1024_unchunked", 0.0, "skipped_over_envelope")
    else:
        out["unchunked"] = "within envelope (not exercised here)"
    engine = RoundEngine(model, fed, cfg, eval_every=rounds,
                         client_chunk=chunk)
    sched = inv_sqrt_lr(0.05)
    lrs = [float(sched(r)) for r in range(1, rounds + 1)]
    t0 = time.time()
    params, _key, logs = engine.run_segment(
        params, jax.random.PRNGKey(cfg.seed), lrs)
    _block(params)
    dt = time.time() - t0
    finite = all(bool(np.isfinite(np.asarray(leaf)).all())
                 for leaf in jax.tree.leaves(params))
    out["chunked_completed"] = finite and logs["mask"].shape == (N,)
    out["chunked_seconds"] = round(dt, 2)
    emit("engine/mem_1024_chunked", dt / rounds * 1e6,
         f"chunk={chunk}|working={chunked_mb:.0f}MB_vs_{unchunked_mb:.0f}MB")
    return out


def run(smoke: bool = False):
    if smoke:
        seed_rounds, segments, seg_len, reps = 30, 2, 15, 3
    else:
        seed_rounds, segments, seg_len, reps = 100, 4, 25, 3
    sizes = (23, 256, 1024)
    results = []
    for N in sizes:
        chunk = 128 if N >= 1024 else None
        model, fed, cfg = _tiny_federation(N, seg_len, client_chunk=chunk)
        rs_seed = time_seed_loop(model, fed, cfg, seed_rounds, reps)
        rs_eng = time_engine(model, fed, cfg, segments, reps)
        results.append({"n_clients": N, "seed_loop_rounds_per_sec":
                        round(rs_seed, 1), "scan_engine_rounds_per_sec":
                        round(rs_eng, 1), "speedup":
                        round(rs_eng / rs_seed, 2),
                        "client_chunk": chunk})
        emit(f"engine/seed_loop_n{N}", 1e6 / rs_seed, f"{rs_seed:.1f}rps")
        emit(f"engine/scan_n{N}", 1e6 / rs_eng,
             f"{rs_eng:.1f}rps|speedup={rs_eng / rs_seed:.2f}x")
    mem = run_memory_section(smoke)

    speed_256 = next(r["speedup"] for r in results if r["n_clients"] == 256)
    acceptance = {"scan_ge_2x_at_n256": speed_256 >= 2.0,
                  "chunked_1024_segment_completes":
                      bool(mem.get("chunked_completed"))}
    return write_report("engine", smoke=smoke, acceptance=acceptance,
                        segment_len=seg_len, segments_per_rep=segments,
                        dispatch=results, memory=mem)


def main():
    from .common import smoke_main
    smoke_main(run)


if __name__ == "__main__":
    main()
